"""Paper Fig. 4 — latent-size ablation on S3D: 'HierAE-N' (hyper-block latent
N) across BAE latent sizes, vs the block-AE 'Baseline' and 'StackAE' (two
stacked residual BAEs).

Claims validated (paper Sec. III-D):
  * compression improves with hyper-block latent size (HierAE-256 > ... > -32
    at comparable NRMSE),
  * the hierarchical setup beats the flat block-AE baseline,
  * stacking a second BAE adds ~nothing over one.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ae_point, dataset, emit, fitted_compressor
from repro.baselines import codec as codec_mod
from repro.baselines.block_ae import BlockAEBaseline
from repro.data.blocks import nrmse, ungroup_hyperblocks


def main(full: bool = False) -> None:
    hb_latents = (32, 64, 128, 256) if full else (32, 128)
    bae_latents = (8, 16, 32, 64) if full else (8, 32)

    for hb_l in hb_latents:
        for bae_l in bae_latents:
            comp, hb = fitted_compressor("s3d", hb_latent=hb_l,
                                         bae_latent=bae_l)
            p = ae_point(comp, hb)
            emit("fig4.hierae", hb_latent=hb_l, bae_latent=bae_l, **p)

    # StackAE: one HBAE + two stacked BAEs
    comp, hb = fitted_compressor("s3d", hb_latent=hb_latents[-1],
                                 bae_latent=bae_latents[0], n_bae_stages=2)
    emit("fig4.stackae", hb_latent=hb_latents[-1], bae_latent=bae_latents[0],
         **ae_point(comp, hb))

    # Baseline: flat block AE (GBAE-style), sweep its latent
    _, hb = dataset("s3d")
    blocks = ungroup_hyperblocks(hb)
    for latent in ((8, 16, 32, 64) if full else (8, 32)):
        base = BlockAEBaseline(in_dim=blocks.shape[1], latent=latent,
                               epochs=12).fit(blocks, seed=0)
        recon, enc = codec_mod.roundtrip(base.codec(), blocks, base.bin_size)
        emit("fig4.baseline", latent=latent,
             cr=round(blocks.size * 4 / enc.nbytes, 2),
             nrmse=float(nrmse(blocks, recon)))


if __name__ == "__main__":
    main()
