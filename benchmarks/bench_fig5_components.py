"""Paper Fig. 5 — component ablation on S3D: Baseline (flat block AE),
HBAE-woa (no self-attention), HBAE (no residual BAE), HierAE (full).

Claim validated: NRMSE(full) < NRMSE(HBAE) and CR-at-equal-error ordering
full > HBAE > HBAE-woa > Baseline — each component earns its place.
"""
from __future__ import annotations

from benchmarks.common import ae_point, dataset, emit, fitted_compressor
from repro.baselines import codec as codec_mod
from repro.baselines.block_ae import BlockAEBaseline
from repro.data.blocks import nrmse, ungroup_hyperblocks


def main(full: bool = False) -> None:
    variants = {
        "full": dict(use_attention=True, use_bae=True),
        "hbae": dict(use_attention=True, use_bae=False),
        "hbae_woa": dict(use_attention=False, use_bae=False),
    }
    for name, kw in variants.items():
        comp, hb = fitted_compressor("s3d", **kw)
        emit(f"fig5.{name}", **ae_point(comp, hb))

    _, hb = dataset("s3d")
    blocks = ungroup_hyperblocks(hb)
    base = BlockAEBaseline(in_dim=blocks.shape[1], latent=16, epochs=12)
    base.fit(blocks, seed=0)
    recon, enc = codec_mod.roundtrip(base.codec(), blocks, base.bin_size)
    emit("fig5.baseline", cr=round(blocks.size * 4 / enc.nbytes, 2),
         nrmse=float(nrmse(blocks, recon)))


if __name__ == "__main__":
    main()
