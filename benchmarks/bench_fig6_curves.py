"""Paper Fig. 6 — CR vs NRMSE against reference compressors (sz-like, zfp-like)
on all three datasets, with the full pipeline incl. GAE error bounds.

The paper's headline: 2-8x higher CR than SZ3 on S3D (multi-variable), up to
3x on E3SM, up to 2x on XGC.  The baselines here are mechanism
reimplementations ("sz-like"/"zfp-like", DESIGN.md §1) on synthetic surrogate
fields, so absolute CRs differ from the paper; what we validate is the
*ordering* at matched NRMSE and that the gap is largest on the
high-dimensional multi-variable S3D data.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, fitted_compressor, gae_point
from repro.baselines import codec as codec_mod
from repro.baselines.szlike import SZLikeCodec
from repro.baselines.zfplike import ZFPLikeCodec
from repro.data.blocks import ungroup_hyperblocks

TAUS = {
    "s3d": (2.0, 1.0, 0.5, 0.2),
    "e3sm": (4.0, 2.0, 1.0, 0.5),
    "xgc": (8.0, 4.0, 2.0, 1.0),
}
EBS = (0.1, 0.05, 0.02, 0.01, 0.005)


def _field(name: str, hb: np.ndarray) -> np.ndarray:
    """Reference compressors see the same normalized data, unblocked into a
    dense array (they exploit smoothness, not blocks)."""
    blocks = ungroup_hyperblocks(hb)
    return blocks.reshape(-1, blocks.shape[1])


def main(full: bool = False) -> None:
    names = ("s3d", "e3sm", "xgc") if full else ("s3d", "e3sm")
    for name in names:
        comp, hb = fitted_compressor(name)
        for tau in TAUS[name] if full else TAUS[name][1:3]:
            emit(f"fig6.{name}.ours", **gae_point(comp, hb, tau))
        field = _field(name, hb)
        bounds = list(EBS if full else EBS[1:4])
        # both reference codecs through the one unified Codec surface; every
        # quoted CR is for a payload that really decodes
        for c, key, label in ((SZLikeCodec(), "eb", "szlike"),
                              (ZFPLikeCodec(), "tol", "zfplike")):
            for r in codec_mod.compression_curve(c, field, bounds,
                                                 bound_key=key):
                emit(f"fig6.{name}.{label}", cr=round(r["cr"], 2),
                     nrmse=float(r["nrmse"]), **{key: r[key]})


if __name__ == "__main__":
    main(full=True)
