"""Paper Fig. 8 — relative point-error histogram at matched compression ratio:
ours vs sz-like vs zfp-like on S3D.

Claim validated: at comparable CR, our relative point errors concentrate at
lower values (we report quantiles of |err| / range instead of a plot).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, fitted_compressor
from repro.baselines import codec as codec_mod
from repro.baselines.szlike import SZLikeCodec
from repro.baselines.zfplike import ZFPLikeCodec
from repro.core.options import CompressOptions
from repro.data.blocks import ungroup_hyperblocks


def _quantiles(orig: np.ndarray, rec: np.ndarray) -> dict:
    rel = np.abs(orig - rec) / max(float(orig.max() - orig.min()), 1e-30)
    qs = np.quantile(rel, [0.5, 0.9, 0.99, 1.0])
    return {"p50": float(qs[0]), "p90": float(qs[1]), "p99": float(qs[2]),
            "max": float(qs[3])}


def main(full: bool = False) -> None:
    comp, hb = fitted_compressor("s3d")
    archive = comp.compress(hb, options=CompressOptions(tau=0.5))
    ours_rec = comp.decompress(archive)
    ours_cr = archive.compression_ratio()
    emit("fig8.ours", cr=round(ours_cr, 1), **_quantiles(hb, ours_rec))

    field = ungroup_hyperblocks(hb)
    # pick each baseline's bound whose CR is closest to ours
    for c, key, name in ((SZLikeCodec(), "eb", "szlike"),
                         (ZFPLikeCodec(), "tol", "zfplike")):
        best = None
        for r in codec_mod.compression_curve(
                c, field, [0.1, 0.05, 0.02, 0.01, 0.005], bound_key=key):
            if best is None or abs(r["cr"] - ours_cr) < abs(best["cr"] - ours_cr):
                best = r
        dec, _ = codec_mod.roundtrip(c, field, best[key])
        emit(f"fig8.{name}", cr=round(best["cr"], 1), **_quantiles(field, dec))


if __name__ == "__main__":
    main()
