"""Paper Fig. 9 — per-species reconstruction error on S3D.

The paper reports per-species NRMSE/CR with the shared latent cost amortized
equally across species.  We reproduce the accounting: per-species NRMSE from
the full pipeline at one tau, with the archive bytes amortized per species.

Claim validated: error is controlled for EVERY species (no species is
sacrificed), which is the point of per-species GAE blocks.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, fitted_compressor
from repro.data.blocks import nrmse


def main(full: bool = False) -> None:
    comp, hb = fitted_compressor("s3d")
    tau = 0.5
    archive = comp.compress(hb, tau=tau)
    recon = comp.decompress(archive)

    # hyper-blocks are (N, k, 58*5*4*4); species axis is the leading block dim
    n, k, d = hb.shape
    n_species = 58
    per = d // n_species
    x = hb.reshape(n * k, n_species, per)
    r = recon.reshape(n * k, n_species, per)
    cr_per_species = archive.compression_ratio() * 1.0  # amortized equally

    errs = []
    for s in range(n_species):
        e = nrmse(x[:, s], r[:, s])
        errs.append(e)
    errs = np.asarray(errs)
    emit("fig9.species", cr_amortized=round(cr_per_species, 1),
         nrmse_mean=float(errs.mean()), nrmse_max=float(errs.max()),
         nrmse_min=float(errs.min()),
         n_species_below_2x_mean=int((errs < 2 * errs.mean()).sum()))
    if full:
        for s in range(n_species):
            emit("fig9.per_species", species=s, nrmse=float(errs[s]))


if __name__ == "__main__":
    main()
