"""Gradient-compression benchmark (the paper's technique on the DP collective,
DESIGN.md §2): communication reduction vs gradient fidelity, and the error-
feedback convergence check — compressed-SGD loss trajectory vs dense SGD on a
small real LM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models.registry import reduced_config
from repro.runtime import grad_compress
from repro.train import optim
from repro.train.loop import init_train_state, make_train_step


def _cos(a, b) -> float:
    fa = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(a)])
    fb = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(b)])
    return float(fa @ fb / (jnp.linalg.norm(fa) * jnp.linalg.norm(fb) + 1e-12))


def main(full: bool = False) -> None:
    # fidelity/ratio sweep on a synthetic gradient pytree
    key = jax.random.PRNGKey(0)
    grads = {"w1": jax.random.normal(key, (512, 512)),
             "w2": jax.random.normal(jax.random.fold_in(key, 1), (2048, 256)),
             "b": jax.random.normal(jax.random.fold_in(key, 2), (2048,))}
    for rank in (8, 32, 128):
        st = grad_compress.init_state(grads, rank=rank)
        ghat, st, stats = grad_compress.compress_update(grads, st)
        # after error feedback, a second step carries the tail
        ghat2, _, _ = grad_compress.compress_update(grads, st)
        emit("gradcomp.fidelity", rank=rank,
             compression=round(float(stats["compression"]), 1),
             cos_step1=round(_cos(grads, ghat), 4),
             cos_step2_with_ef=round(_cos(grads, ghat2), 4))

    # convergence: tiny LM, dense vs compressed+EF
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    steps = 30 if full else 12
    losses = {}
    for mode in ("none", "pca_ef"):
        run = RunConfig(gradient_compression=mode, grad_comp_rank=32)
        opt = optim.adam(1e-3)
        state = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)
        step = jax.jit(make_train_step(cfg, run, opt))
        rng = np.random.default_rng(0)
        cur = []
        for i in range(steps):
            toks = rng.integers(0, cfg.vocab, (4, 64)).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
            state, m = step(state, batch)
            cur.append(float(m["loss"]))
        losses[mode] = cur
    emit("gradcomp.convergence", steps=steps,
         dense_final=round(losses["none"][-1], 4),
         compressed_final=round(losses["pca_ef"][-1], 4),
         gap=round(losses["pca_ef"][-1] - losses["none"][-1], 4))

    # tau-driven GAE mode: guaranteed per-block bound on the gradient
    g = {"w": jax.random.normal(key, (1024, 256))}
    bounded, stats = grad_compress.gae_compress_grads(g, tau=0.5)
    blocks = np.asarray(g["w"]).reshape(-1, 256)
    rblocks = np.asarray(bounded["w"]).reshape(-1, 256)
    errs = np.linalg.norm(blocks - rblocks, axis=1)
    emit("gradcomp.gae_bound", tau=0.5, max_block_err=round(float(errs.max()), 4),
         keep_frac=round(float(stats["keep_frac"]), 4))


if __name__ == "__main__":
    main()
