"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle on CPU.

On this container the Pallas kernels execute via the interpreter, so wall
times mean nothing for TPU — what IS meaningful and reported here:
  * correctness deltas vs the oracle at benchmark shapes,
  * the jnp-oracle wall time (the actual CPU compute being modeled),
  * the kernels' VMEM working-set estimates (static, from BlockSpecs).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def main(full: bool = False) -> None:
    key = jax.random.PRNGKey(0)

    # flash attention (paper-agnostic LM hot-spot)
    from repro.kernels.flash_attention import ops as fa, ref as fa_ref
    b, s, h, kv, hd = (2, 1024, 8, 2, 64) if full else (1, 512, 4, 2, 64)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    t_ref = _time(jax.jit(lambda q, k, v: fa_ref.flash_attention_ref(q, k, v)),
                  q, k, v)
    err = float(jnp.max(jnp.abs(fa.flash_attention(q, k, v) -
                                fa_ref.flash_attention_ref(q, k, v))))
    vmem_kb = (128 * hd * 3 + 128 * hd) * 4 // 1024
    emit("kernel.flash_attention", shape=f"{b}x{s}x{h}x{hd}",
         ref_ms=round(t_ref * 1e3, 1), max_err=err, vmem_tile_kb=vmem_kb)

    # hyper-block attention (HBAE)
    from repro.kernels.block_attention import ops as ba, ref as ba_ref
    nB, n, d = (4096, 10, 128) if full else (512, 10, 128)
    ks = jax.random.split(key, 3)
    q, k, v = (jax.random.normal(kk, (nB, n, d), jnp.float32) for kk in ks)
    t_ref = _time(jax.jit(lambda q, k, v: ba_ref.block_attention_ref(q, k, v)),
                  q, k, v)
    err = float(jnp.max(jnp.abs(ba.block_attention(q, k, v) -
                                ba_ref.block_attention_ref(q, k, v))))
    emit("kernel.block_attention", shape=f"{nB}x{n}x{d}",
         ref_ms=round(t_ref * 1e3, 1), max_err=err,
         vmem_tile_kb=256 * n * d * 4 * 4 // 1024)

    # GAE projection
    from repro.kernels.gae_project import ops as gp, ref as gp_ref
    nb, dd = (8192, 1521) if full else (2048, 256)
    ks = jax.random.split(key, 2)
    r = jax.random.normal(ks[0], (nb, dd), jnp.float32)
    u = jax.random.normal(ks[1], (dd, dd), jnp.float32) / np.sqrt(dd)
    t_ref = _time(jax.jit(lambda r, u: gp_ref.gae_project_ref(r, u)), r, u)
    c, _ = gp.gae_project(r, u)
    ce, _ = gp_ref.gae_project_ref(r, u)
    emit("kernel.gae_project", shape=f"{nb}x{dd}",
         ref_ms=round(t_ref * 1e3, 1),
         max_err=float(jnp.max(jnp.abs(c - ce))),
         vmem_tile_kb=(256 * 512 + 512 * 512 + 2 * 256 * 512) * 4 // 1024)

    # fused quantize
    from repro.kernels.quantize import ops as qz, ref as qz_ref
    x = jax.random.normal(key, (1 << 20,), jnp.float32)
    t_ref = _time(jax.jit(lambda x: qz_ref.quantize_fused_ref(x, 0.01)), x)
    qk, dk, ek = qz.quantize_fused(x, 0.01)
    qr, dr, er = qz_ref.quantize_fused_ref(x, 0.01)
    # ties at bin boundaries may flip by 1 ulp between the kernel's true
    # division and XLA's multiply-by-reciprocal; both stay within bin/2.
    mism = int(jnp.sum(jnp.abs(qk - qr) > 1))
    emit("kernel.quantize", n=x.size, ref_ms=round(t_ref * 1e3, 1),
         off_by_more_than_1=mism,
         tie_flips=int(jnp.sum(qk != qr)))

    # SSD scan
    from repro.kernels.ssd_scan import ops as sd, ref as sd_ref
    b2, s2, h2, p2, n2 = (2, 512, 8, 64, 64) if full else (1, 256, 4, 32, 32)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b2, s2, h2, p2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b2, s2, h2), jnp.float32))
    a_log = jax.random.uniform(ks[2], (h2,), jnp.float32, 0.0, 1.0)
    bb = jax.random.normal(ks[3], (b2, s2, 1, n2), jnp.float32)
    cc = jax.random.normal(ks[4], (b2, s2, 1, n2), jnp.float32)
    t_ref = _time(jax.jit(lambda *a: sd_ref.ssd_scan_ref(*a, 128)),
                  x, dt, a_log, bb, cc)
    y1, _ = sd.ssd(x, dt, a_log, bb, cc, chunk=128)
    y2, _ = sd_ref.ssd_scan_ref(x, dt, a_log, bb, cc, 128)
    emit("kernel.ssd_scan", shape=f"{b2}x{s2}x{h2}x{p2}",
         ref_ms=round(t_ref * 1e3, 1),
         max_err=float(jnp.max(jnp.abs(y1 - y2))),
         vmem_state_kb=p2 * n2 * 4 // 1024)


if __name__ == "__main__":
    main()
