"""Before/after throughput benchmark for the compression hot path.

Measures the LEGACY hot path — a faithful re-implementation of the
pre-exec-layer pipeline: fresh inline ``jax.jit(fn)(...)`` wrappers per call
(so every call retraces + recompiles), per-stage host<->device ``np.asarray``
bounces, serial chunk entropy loops, and the per-symbol scalar Huffman decode
— against the current pipeline (persistent jit cache, fused device-resident
stage programs, chunk-parallel vectorized entropy coding), on a synthetic
S3D-shaped workload.  Results (values/s per phase, speedup, retrace counts)
are written to ``BENCH_pipeline.json``.

    PYTHONPATH=src python benchmarks/bench_pipeline_throughput.py            # full
    PYTHONPATH=src python benchmarks/bench_pipeline_throughput.py --smoke    # CI gate

``--smoke`` runs a small workload and FAILS (exit 1) if a repeated
``compress``/``decompress`` call retraces after warmup — the regression gate
wired into ``scripts/smoke.sh``.  See docs/PERF.md for how to read the output.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bae as bae_mod
from repro.core import entropy, gae
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core.pipeline import Archive, ArchiveChunk, HierarchicalCompressor
from repro.core.quantization import dequantize, quantize
from repro.data import blocks as blocks_mod
from repro.data import synthetic


# ---------------------------------------------------------------------------
# legacy (pre-PR) hot path, kept verbatim as the measured baseline
# ---------------------------------------------------------------------------

def legacy_gae_encode_blocks(x, x_r, basis, tau, bin_size, max_refine=20):
    """Pre-PR encoder: unjitted selection + one Python iteration per block."""
    x = np.asarray(x, np.float32)
    x_r = np.asarray(x_r, np.float32)
    u = np.asarray(basis, np.float32)
    n, d = x.shape
    sel = jax.device_get(gae.gae_select(jnp.asarray(x - x_r), jnp.asarray(u),
                                        tau, bin_size))
    out = x_r + np.asarray(sel.corrected)
    codes = []
    for i in range(n):
        m = int(sel.m[i])
        bin_exp = 0
        b = bin_size
        idx = np.asarray(sel.order[i][:m], np.int32)
        q = np.asarray(sel.q_sorted[i][:m], np.int64)
        err = float(np.linalg.norm(x[i] - out[i]))
        while err > tau and bin_exp < max_refine:
            if m < d:
                m = min(d, m + max(1, d // 32))
            else:
                bin_exp += 1
                b = bin_size / (2 ** bin_exp)
            c = u.T @ (x[i] - x_r[i])
            order = np.argsort(-np.square(c))
            idx = order[:m].astype(np.int32)
            q = np.round(c[idx] / b).astype(np.int64)
            rec = x_r[i] + u[:, idx] @ (q.astype(np.float32) * b)
            err = float(np.linalg.norm(x[i] - rec))
            out[i] = rec
        codes.append(gae.GAEBlockCode(m=m, indices=idx, qcoeffs=q,
                                      bin_exp=bin_exp))
    return out, codes


def legacy_gae_decode_blocks(x_r, basis, codes, bin_size):
    """Pre-PR decoder: one gather-matvec per block."""
    u = np.asarray(basis, np.float32)
    out = np.asarray(x_r, np.float32).copy()
    for i, code in enumerate(codes):
        if code.m == 0:
            continue
        b = bin_size / (2 ** code.bin_exp)
        out[i] = out[i] + u[:, code.indices] @ (code.qcoeffs.astype(np.float32)
                                                * b)
    return out


def legacy_encode_index_sets(index_sets, dim):
    """Pre-PR bitmask encoder: one mask allocation per index set."""
    import struct
    import zlib
    lengths = []
    all_bits = []
    for idx in index_sets:
        mask = np.zeros(dim, np.uint8)
        if idx.size:
            mask[idx] = 1
            plen = int(idx.max()) + 1
        else:
            plen = 0
        lengths.append(plen)
        all_bits.append(mask[:plen])
    bits = np.concatenate(all_bits) if all_bits else np.zeros(0, np.uint8)
    header = struct.pack("<II", len(index_sets), dim)
    lens_b = np.asarray(lengths, np.uint32).tobytes()
    payload = np.packbits(bits).tobytes() if bits.size else b""
    return zlib.compress(header + lens_b + payload, level=9)


def legacy_decode_index_sets(blob, expect_dim=None, expect_sets=None):
    """Pre-PR bitmask decoder: per-set slice + nonzero loop (validation
    identical to the current implementation)."""
    import struct
    import zlib
    from repro.core.errors import MalformedStream, TruncatedArchive
    try:
        raw = zlib.decompress(blob)
    except zlib.error as e:
        raise MalformedStream(f"index blob DEFLATE error: {e}") from e
    if len(raw) < 8:
        raise TruncatedArchive("index blob shorter than its header")
    n, dim = struct.unpack("<II", raw[:8])
    if expect_dim is not None and dim != expect_dim:
        raise MalformedStream(
            f"index blob dimension {dim} != basis dimension {expect_dim}")
    if expect_sets is not None and n != expect_sets:
        raise MalformedStream(f"index blob has {n} sets, expected {expect_sets}")
    if len(raw) < 8 + 4 * n:
        raise TruncatedArchive("index blob length table truncated")
    lens = np.frombuffer(raw[8:8 + 4 * n], np.uint32).astype(np.int64)
    if lens.size and lens.max() > dim:
        raise MalformedStream(
            f"index prefix length {int(lens.max())} exceeds dimension {dim}")
    bits = np.unpackbits(np.frombuffer(raw[8 + 4 * n:], np.uint8))
    if int(lens.sum()) > bits.size:
        raise TruncatedArchive("index bitmask payload truncated")
    out = []
    pos = 0
    for plen in lens:
        mask = bits[pos:pos + plen]
        out.append(np.nonzero(mask)[0].astype(np.int32))
        pos += int(plen)
    return out


def legacy_compress(comp: HierarchicalCompressor, hyperblocks: np.ndarray,
                    tau: float, chunk_hyperblocks: int = 64) -> Archive:
    """The old ``compress``: inline jit per call site, one host<->device
    round-trip per stage, serial chunk striping."""
    cfg = comp.cfg
    n, k, d = hyperblocks.shape
    latent = np.asarray(jax.jit(hbae_mod.hbae_encode)(comp.hbae_params,
                                                      jnp.asarray(hyperblocks)))
    q_lh = np.asarray(quantize(jnp.asarray(latent), cfg.hb_bin))
    lat_deq = np.asarray(dequantize(jnp.asarray(q_lh), cfg.hb_bin))
    y = np.asarray(jax.jit(hbae_mod.hbae_decode)(comp.hbae_params,
                                                 jnp.asarray(lat_deq)))
    recon = y
    q_lbs: list[np.ndarray] = []
    if cfg.use_bae:
        resid = (hyperblocks - recon).reshape(n * k, d)
        for p in comp.bae_params:
            lb = np.asarray(jax.jit(bae_mod.bae_encode)(p, jnp.asarray(resid)))
            q_lb = np.asarray(quantize(jnp.asarray(lb), cfg.bae_bin))
            q_lbs.append(q_lb)
            lb_deq = np.asarray(dequantize(jnp.asarray(q_lb), cfg.bae_bin))
            r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, jnp.asarray(lb_deq)))
            recon = recon + r_hat.reshape(n, k, d)
            resid = resid - r_hat

    codes: list[gae.GAEBlockCode] = []
    gae_dim = 0
    if tau is not None:
        x_gae = comp._gae_view(hyperblocks)
        r_gae = comp._gae_view(recon)
        _, codes = legacy_gae_encode_blocks(x_gae, r_gae, comp.basis, tau,
                                            cfg.gae_bin)
        gae_dim = int(comp.basis.shape[0])

    width = comp._chunk_width(chunk_hyperblocks, with_gae=tau is not None)
    d_gae = cfg.gae_block_elems or cfg.block_elems
    gae_per_hb = (k * d) // d_gae if tau is not None else 0
    chunks = []
    for start in range(0, n, width):
        n_hb = min(width, n - start)
        hb_stream = entropy.huffman_compress(q_lh[start:start + n_hb])
        bae_streams = [entropy.huffman_compress(
            q_lb[start * k:(start + n_hb) * k]) for q_lb in q_lbs]
        coeff_stream = None
        index_blob = binexp_blob = b""
        if tau is not None:
            cchunk = codes[start * gae_per_hb:(start + n_hb) * gae_per_hb]
            all_coeffs, index_sets, binexps = [], [], []
            for c in cchunk:
                asc = np.argsort(c.indices)
                index_sets.append(np.sort(c.indices))
                all_coeffs.append(c.qcoeffs[asc])
                binexps.append(c.bin_exp)
            coeffs = (np.concatenate(all_coeffs) if all_coeffs else
                      np.zeros(0, np.int64))
            if coeffs.size:
                coeff_stream = entropy.huffman_compress(coeffs)
            index_blob = legacy_encode_index_sets(index_sets, gae_dim)
            binexp_blob = entropy.zlib_pack(
                np.asarray(binexps, np.uint8).tobytes())
        chunks.append(ArchiveChunk(
            hb_start=start, n_hyperblocks=n_hb, hb_stream=hb_stream,
            bae_streams=bae_streams, gae_coeff_stream=coeff_stream,
            gae_index_blob=index_blob, gae_binexp_blob=binexp_blob))
    return Archive(n_hyperblocks=n, n_values=hyperblocks.size,
                   chunk_hyperblocks=width, gae_dim=gae_dim, chunks=chunks)


def legacy_decompress(comp: HierarchicalCompressor, archive: Archive
                      ) -> np.ndarray:
    """The old strict ``decompress``: serial chunk loop, inline jit decode."""
    cfg = comp.cfg
    n, k, d = archive.n_hyperblocks, cfg.k, cfg.block_elems
    q_lh = np.zeros((n, cfg.hb_latent), np.int64)
    q_lbs = [np.zeros((n * k, cfg.bae_latent), np.int64)
             for _ in comp.bae_params]
    gae_codes: dict[int, gae.GAEBlockCode] = {}
    d_gae = cfg.gae_block_elems or d
    gae_per_hb = (k * d) // d_gae if archive.gae_dim else 0
    for chunk in archive.chunks:
        c_lh, c_lbs, c_codes = comp._decode_chunk(chunk, archive)
        s, e = chunk.hb_start, chunk.hb_start + chunk.n_hyperblocks
        q_lh[s:e] = c_lh
        for stage, c_lb in enumerate(c_lbs):
            q_lbs[stage][s * k:e * k] = c_lb
        for j, code in enumerate(c_codes):
            gae_codes[s * gae_per_hb + j] = code
    lat = np.asarray(dequantize(jnp.asarray(q_lh), cfg.hb_bin))
    recon = np.asarray(jax.jit(hbae_mod.hbae_decode)(comp.hbae_params,
                                                     jnp.asarray(lat)))
    for p, q_lb in zip(comp.bae_params, q_lbs):
        lb = np.asarray(dequantize(jnp.asarray(q_lb), cfg.bae_bin))
        r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, jnp.asarray(lb)))
        recon = recon + r_hat.reshape(n, k, d)
    if archive.gae_dim and gae_codes:
        r_gae = comp._gae_view(recon)
        idxs = sorted(gae_codes)
        sub = legacy_gae_decode_blocks(r_gae[idxs], comp.basis,
                                       [gae_codes[i] for i in idxs],
                                       cfg.gae_bin)
        r_gae[idxs] = sub
        recon = comp._gae_unview(r_gae, recon.shape)
    return recon


@contextlib.contextmanager
def legacy_entropy():
    """Route the entropy codecs through their pre-PR implementations (scalar
    per-symbol Huffman decode, per-set index bitmask loops) for the duration
    of the legacy measurement — including inside ``comp._decode_chunk``."""
    saved = (entropy.huffman_decode, entropy.decode_index_sets)
    entropy.huffman_decode = entropy.huffman_decode_scalar
    entropy.decode_index_sets = legacy_decode_index_sets
    try:
        yield
    finally:
        entropy.huffman_decode, entropy.decode_index_sets = saved


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def s3d_workload(smoke: bool, seed: int, epochs_scale: float):
    """S3D-shaped hyper-blocks: paper block geometry (58,5,4,4), k=10."""
    if not smoke:
        cfg, hb = synthetic.make_dataset("s3d", quick=True, seed=seed,
                                         epochs_scale=epochs_scale)
        return cfg, hb
    # smoke: same geometry, smaller spatial grid (t stays 50 so t_grid >= k)
    import dataclasses
    from repro.configs import get_compressor_config
    data = synthetic.s3d_like(n_species=58, t=50, h=16, w=16, seed=seed)
    norm = blocks_mod.Normalizer.fit(data, mode="range", axis=0)
    blocks, meta = blocks_mod.block_nd(norm.forward(data),
                                       (data.shape[0], 5, 4, 4))
    blocks = synthetic._temporal_major(blocks, meta.grid_shape, t_axis=1)
    hb = blocks_mod.group_hyperblocks(blocks, 10)
    cfg = dataclasses.replace(get_compressor_config("s3d"), hidden=128,
                              bae_hidden=128, epochs_hbae=2, epochs_bae=2)
    return cfg, hb.astype(np.float32)


def timed(fn, repeats: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + retrace-regression gate (exit 1)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--legacy-repeats", type=int, default=2)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epochs-scale", type=float, default=0.1)
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.repeats = min(args.repeats, 2)
        args.legacy_repeats = 1

    cfg, hb = s3d_workload(args.smoke, args.seed, args.epochs_scale)
    print(f"workload: {hb.shape[0]} hyper-blocks of (k={hb.shape[1]}, "
          f"D={hb.shape[2]}) = {hb.size:,} values", file=sys.stderr)
    t0 = time.perf_counter()
    comp = HierarchicalCompressor(cfg).fit(hb, seed=args.seed)
    comp.fit_basis(hb)
    print(f"fit in {time.perf_counter() - t0:.1f}s", file=sys.stderr)

    # -- current path: warmup, then assert zero retraces across repeats -----
    exec_mod.reset_stage_stats()
    archive = comp.compress(hb, tau=args.tau)
    recon = comp.decompress(archive)
    traces_warm = exec_mod.total_retraces()
    cur_comp = timed(lambda: comp.compress(hb, tau=args.tau), args.repeats)
    cur_dec = timed(lambda: comp.decompress(archive), args.repeats)
    retrace_delta = exec_mod.total_retraces() - traces_warm

    # -- legacy path --------------------------------------------------------
    with legacy_entropy():
        leg_arch = legacy_compress(comp, hb, args.tau)
        leg_recon = legacy_decompress(comp, leg_arch)
        leg_comp = timed(lambda: legacy_compress(comp, hb, args.tau),
                         args.legacy_repeats)
        leg_dec = timed(lambda: legacy_decompress(comp, leg_arch),
                        args.legacy_repeats)
    # Selection ties may resolve differently between the two implementations,
    # so compare them on the contract: every block meets the l2 bound.
    for label, r in (("legacy", leg_recon), ("current", recon)):
        gview = (hb - r).reshape(-1, cfg.gae_block_elems or cfg.block_elems)
        worst = float(np.linalg.norm(gview, axis=1).max())
        if worst > args.tau * (1 + 1e-5):
            print(f"ERROR: {label} reconstruction violates tau: "
                  f"{worst} > {args.tau}", file=sys.stderr)
            return 1

    speedup = (leg_comp + leg_dec) / (cur_comp + cur_dec)
    result = {
        "workload": {"dataset": "s3d", "smoke": args.smoke,
                     "hyperblocks": int(hb.shape[0]), "k": int(hb.shape[1]),
                     "block_elems": int(hb.shape[2]),
                     "n_values": int(hb.size), "tau": args.tau,
                     "repeats": args.repeats,
                     "legacy_repeats": args.legacy_repeats},
        "baseline": {
            "compress_s": leg_comp, "decompress_s": leg_dec,
            "compress_values_per_s": hb.size / leg_comp,
            "decompress_values_per_s": hb.size / leg_dec,
        },
        "current": {
            "compress_s": cur_comp, "decompress_s": cur_dec,
            "compress_values_per_s": hb.size / cur_comp,
            "decompress_values_per_s": hb.size / cur_dec,
            "stage_stats": {
                name: {"calls": st.calls, "seconds": round(st.seconds, 4),
                       "values_per_s": round(st.values_per_s(), 1)}
                for name, st in sorted(exec_mod.stage_stats().items())},
            "retraces": exec_mod.retrace_counts(),
        },
        "speedup_compress_plus_decompress": round(speedup, 2),
        "retraces_after_warmup": int(retrace_delta),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"legacy:  compress {leg_comp:.3f}s  decompress {leg_dec:.3f}s")
    print(f"current: compress {cur_comp:.3f}s  decompress {cur_dec:.3f}s")
    print(f"speedup (compress+decompress): {speedup:.2f}x")
    print(f"retraces after warmup: {retrace_delta}")
    print(f"written: {args.out}")

    if args.smoke and retrace_delta != 0:
        print(f"FAIL: {retrace_delta} retraces across repeated "
              f"compress/decompress calls after warmup (expected 0) — a hot-"
              f"path call site is creating fresh jit wrappers", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
