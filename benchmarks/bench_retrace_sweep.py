"""Retrace-budget sweep: trace count must equal distinct-shape count.

The ROADMAP's "retrace budget in CI" item: sweep the compress path over
(n_hyperblocks, n_bae_stages) combinations and assert that the persistent jit
cache (``core/exec.py``) traces each fused program EXACTLY once per distinct
(bae-stage structure, stripe shape) key — no retraces for repeated shapes, no
hidden fresh-wrapper call sites.

Both the batch and streaming compress paths run per-stripe programs on the
same ``stripe_spans`` tiling, so the expected trace count is computable in
closed form: for each of ``encode_frontend`` / ``decode_backend``, the number
of distinct ``(n_bae_stages, stripe_width)`` pairs the sweep produces.  The
sweep runs batch AND streaming compress on every combination — streaming must
add ZERO traces on top of batch (it reuses the same cached programs; that is
what makes its chunks byte-identical).

    PYTHONPATH=src python benchmarks/bench_retrace_sweep.py
    PYTHONPATH=src python benchmarks/bench_retrace_sweep.py --out BENCH_retrace.json
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.core import bae as bae_mod
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core.pipeline import CompressorConfig, HierarchicalCompressor
from repro.stream import stream_compress


def make_compressor(n_stages: int, seed: int) -> HierarchicalCompressor:
    """Random-init compressor (no training — the sweep measures tracing, not
    reconstruction quality)."""
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32, hb_latent=8,
                           bae_hidden=32, bae_latent=4, use_bae=n_stages > 0,
                           n_bae_stages=max(n_stages, 1), hb_bin=0.01,
                           bae_bin=0.01)
    comp = HierarchicalCompressor(cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), 1 + max(n_stages, 1))
    comp.hbae_params = hbae_mod.hbae_init(
        keys[0], in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb,
        hidden=cfg.hidden, latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [
        bae_mod.bae_init(keys[1 + s], in_dim=cfg.block_elems,
                         hidden=cfg.bae_hidden, latent=cfg.bae_latent)
        for s in range(n_stages)]
    return comp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hyperblocks", type=int, nargs="+", default=[12, 24])
    ap.add_argument("--bae-stages", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--chunk-hyperblocks", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    combos = [(n_hb, stages) for stages in args.bae_stages
              for n_hb in args.hyperblocks]

    # closed-form expectation: one trace per distinct (structure, shape) key
    distinct: set[tuple[int, int]] = set()
    per_combo_spans = {}
    for n_hb, stages in combos:
        comp = make_compressor(stages, args.seed)
        spans = comp.stripe_spans(n_hb, args.chunk_hyperblocks, with_gae=False)
        per_combo_spans[(n_hb, stages)] = spans
        for _, width in spans:
            distinct.add((stages, width))
    expected = 2 * len(distinct)        # encode_frontend + decode_backend

    base = exec_mod.total_retraces()
    for n_hb, stages in combos:
        comp = make_compressor(stages, rng.integers(1 << 30))
        x = rng.normal(size=(n_hb, 2, 40)).astype(np.float32)
        comp.compress(x, tau=None,
                      chunk_hyperblocks=args.chunk_hyperblocks)
        batch_traces = exec_mod.total_retraces()
        stream_compress(comp, x, tau=None,
                        chunk_hyperblocks=args.chunk_hyperblocks)
        stream_delta = exec_mod.total_retraces() - batch_traces
        if stream_delta:
            print(f"FAIL: streaming compress added {stream_delta} traces on "
                  f"(n_hb={n_hb}, stages={stages}) — it must hit the batch "
                  f"path's cache", file=sys.stderr)
            return 1
    got = exec_mod.total_retraces() - base

    report = {
        "combos": [{"n_hyperblocks": n, "bae_stages": s,
                    "stripe_widths": sorted({w for _, w in
                                             per_combo_spans[(n, s)]})}
                   for n, s in combos],
        "distinct_shape_keys": sorted(distinct),
        "expected_traces": expected,
        "observed_traces": got,
        "retrace_counts": exec_mod.retrace_counts(),
    }
    print(f"distinct (bae_stages, stripe_width) keys: {len(distinct)} -> "
          f"expected {expected} traces (encode+decode), observed {got}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"written: {args.out}")
    if got != expected:
        print(f"FAIL: trace count {got} != distinct-shape count {expected}: "
              f"{exec_mod.retrace_counts()}", file=sys.stderr)
        return 1
    print("OK: trace count equals distinct-shape count")
    return 0


if __name__ == "__main__":
    sys.exit(main())
