"""Mesh-sharded stage pipeline benchmark: parity, dispatch scaling, timing.

Runs the SAME workload through the single-device fused stage programs and
the ``shard_map``-sharded ones (hyper-block data axis over a
``jax.sharding.Mesh``) and records into ``BENCH_shard.json``:

* **parity** (hard gate): the sharded batch archive AND the sharded
  streaming container are byte-identical to the single-device archive;
* **retraces** (hard gate): after one warmup pass, re-running both paths
  triggers zero new traces — the mesh-keyed ``JitCache`` keeps the sharded
  and unsharded program sets live side by side;
* **dispatch scaling** (hard gate): with ``N`` shards the sharded encode
  makes ~1/N as many device dispatches (aligned stripe groups collapse into
  one ``shard_map`` call each);
* **timing**: encode wall clock per path.  Virtual CPU devices
  (``--xla_force_host_platform_device_count``) share the physical cores, so
  a wall-clock speedup gate is enforced only when the machine has at least
  as many usable cores as shards — on CI this records honest numbers
  without failing on hardware that cannot physically go faster.

Device count is frozen at first jax import, so this benchmark force-sets
``XLA_FLAGS`` at module import time (before jax loads) from ``--devices``:

    PYTHONPATH=src python benchmarks/bench_shard.py            # 4 shards
    PYTHONPATH=src python benchmarks/bench_shard.py --smoke    # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time


def _want_devices(argv) -> int:
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return int(os.environ.get("REPRO_BENCH_SHARD_DEVICES", "4"))


DEVICES = _want_devices(sys.argv[1:])
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        (_flags + f" --xla_force_host_platform_device_count={DEVICES}").strip()

import numpy as np                                              # noqa: E402

import jax                                                      # noqa: E402

from repro.core import CompressorConfig, HierarchicalCompressor  # noqa: E402
from repro.core import bae as bae_mod                           # noqa: E402
from repro.core import exec as exec_mod                         # noqa: E402
from repro.core import hbae as hbae_mod                         # noqa: E402
from repro.core.options import CompressOptions                  # noqa: E402
from repro.runtime import archive_io                            # noqa: E402
from repro.stream import stream_compress                        # noqa: E402


def _make_comp(n_hb: int, block_elems: int, seed: int = 0
               ) -> tuple[HierarchicalCompressor, np.ndarray]:
    """Random-init compressor: the stage programs run the same compute
    graph as a trained one, and parity/scaling don't depend on weights."""
    cfg = CompressorConfig(block_elems=block_elems, k=4, emb=32, hidden=64,
                           hb_latent=16, bae_hidden=64, bae_latent=8,
                           gae_block_elems=2 * block_elems,
                           hb_bin=0.01, bae_bin=0.01, gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(seed))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(seed)
    hb = 0.1 * rng.standard_normal(
        (n_hb, cfg.k, cfg.block_elems)).astype(np.float32)
    comp.fit_basis(hb)
    return comp, hb


def _timed(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload, 1 repeat, parity/retrace/dispatch "
                    "gates only")
    ap.add_argument("--devices", type=int, default=DEVICES,
                    help="virtual device count = mesh shards (must be set "
                    "before jax initializes; this script handles that)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--hyperblocks", type=int, default=None,
                    help="workload size (default: 32 smoke / 128 full)")
    ap.add_argument("--chunk-hyperblocks", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.repeats = 1

    n_dev = len(jax.devices())
    if n_dev < args.devices:
        print(f"FAIL: need {args.devices} devices, jax sees {n_dev} "
              f"(XLA_FLAGS applied too late?)", file=sys.stderr)
        return 1

    n_hb = args.hyperblocks or (32 if args.smoke else 128)
    block_elems = 40 if args.smoke else 128
    comp, hb = _make_comp(n_hb, block_elems, args.seed)
    print(f"workload: {n_hb} hyper-blocks of (k={hb.shape[1]}, "
          f"D={hb.shape[2]}) = {hb.size:,} values, {args.devices} shards",
          file=sys.stderr)

    base_opts = CompressOptions(tau=args.tau,
                                chunk_hyperblocks=args.chunk_hyperblocks)
    mesh_opts = base_opts.replace(mesh=args.devices)

    # -- warmup + parity -----------------------------------------------------
    single = comp.compress(hb, options=base_opts)
    sharded = comp.compress(hb, options=mesh_opts)
    blob_single = archive_io.serialize_archive(single)
    parity_batch = archive_io.serialize_archive(sharded) == blob_single

    tmpdir = tempfile.mkdtemp(prefix="bench_shard_")
    stream_path = os.path.join(tmpdir, "stream.rba")
    result = stream_compress(comp, hb, options=mesh_opts,
                             out_path=stream_path)
    with open(stream_path, "rb") as f:
        parity_stream = f.read() == blob_single

    # -- retrace gate --------------------------------------------------------
    traces_warm = exec_mod.total_retraces()
    comp.compress(hb, options=base_opts)
    comp.compress(hb, options=mesh_opts)
    retrace_delta = exec_mod.total_retraces() - traces_warm

    # -- dispatch scaling ----------------------------------------------------
    # single-device encode = one device dispatch per stripe; the sharded
    # path collapses every aligned group of N stripes into ONE shard_map
    # dispatch (counted by the mesh.sharded_groups counter)
    n_stripes = -(-n_hb // args.chunk_hyperblocks)
    exec_mod.reset_stage_stats()
    comp.compress(hb, options=mesh_opts)
    cnt = exec_mod.counters()
    group_dispatches = int(cnt.get("mesh.sharded_groups", 0))
    expect_groups = (n_hb // args.chunk_hyperblocks) // args.devices
    tail_dispatches = n_stripes - group_dispatches * args.devices
    sharded_dispatches = group_dispatches + tail_dispatches
    single_calls = n_stripes
    dispatch_ok = (group_dispatches == expect_groups
                   and int(cnt.get("mesh.shards", 0)) == args.devices
                   and sharded_dispatches < single_calls)

    # -- timing --------------------------------------------------------------
    single_s = _timed(lambda: comp.compress(hb, options=base_opts),
                      args.repeats)
    sharded_s = _timed(lambda: comp.compress(hb, options=mesh_opts),
                       args.repeats)
    speedup = single_s / sharded_s if sharded_s > 0 else 0.0
    usable = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    speedup_gate = usable >= args.devices

    out = {
        "workload": {"smoke": args.smoke, "hyperblocks": n_hb,
                     "k": int(hb.shape[1]), "block_elems": int(hb.shape[2]),
                     "n_values": int(hb.size), "tau": args.tau,
                     "chunk_hyperblocks": args.chunk_hyperblocks,
                     "n_stripes": n_stripes, "repeats": args.repeats},
        "machine": {"cpu_count": os.cpu_count(), "usable_cores": usable,
                    "devices": n_dev, "shards": args.devices,
                    "jax_backend": jax.default_backend(),
                    "speedup_gate_enforced": speedup_gate},
        "parity": {"batch_byte_identical": parity_batch,
                   "stream_byte_identical": parity_stream,
                   "archive_bytes": len(blob_single),
                   "stream_items": result.stats.n_items},
        "dispatch": {"single_device_calls": int(single_calls),
                     "sharded_group_calls": int(group_dispatches),
                     "sharded_tail_calls": int(tail_dispatches),
                     "expected_group_calls": int(expect_groups)},
        "timing": {"single_encode_s": round(single_s, 4),
                   "sharded_encode_s": round(sharded_s, 4),
                   "speedup": round(speedup, 3)},
        "retraces_after_warmup": int(retrace_delta),
        "retrace_counts": exec_mod.retrace_counts(),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"single: {single_s:.3f}s  sharded: {sharded_s:.3f}s  "
          f"speedup {speedup:.2f}x on {usable} usable core(s)")
    print(f"parity: batch={parity_batch} stream={parity_stream}")
    print(f"dispatch: {single_calls} single-device calls -> "
          f"{group_dispatches} group + {tail_dispatches} tail")
    print(f"written: {args.out}")

    ok = True
    if not (parity_batch and parity_stream):
        print("FAIL: sharded archives are not byte-identical to "
              "single-device", file=sys.stderr)
        ok = False
    if retrace_delta != 0:
        print(f"FAIL: {retrace_delta} retraces after warmup "
              f"({exec_mod.retrace_counts()})", file=sys.stderr)
        ok = False
    if not dispatch_ok:
        print(f"FAIL: dispatch scaling broken — {group_dispatches} group "
              f"calls (expected {expect_groups}), {sharded_dispatches} total "
              f"vs {single_calls} single-device", file=sys.stderr)
        ok = False
    if speedup_gate and speedup < 1.1:
        print(f"FAIL: speedup {speedup:.2f}x < 1.1x with {usable} usable "
              f"cores >= {args.devices} shards", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
