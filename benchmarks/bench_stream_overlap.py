"""Streaming-vs-batch compress benchmark: parity, overlap, speedup.

Runs the SAME quick S3D workload through

* the batch path — ``HierarchicalCompressor.compress`` followed by
  ``archive_io.write_archive`` (everything serialized in memory, one atomic
  write at the end), and
* the streaming path — ``repro.stream.stream_compress`` (device dispatch /
  transfer / host coding pipelined, chunk sections appended to disk as they
  complete),

and records into ``BENCH_stream.json``:

* **parity** (hard gate, any mode): the streamed container file is
  byte-identical to ``serialize_archive`` of the batch archive, and
  ``compressed_bytes()`` match,
* **overlap** (hard gate, any mode): measured wall time with >= 2 pipeline
  stages simultaneously busy must be > 0,
* **speedup**: end-to-end (compress + write) wall clock, batch / stream.

Honest-hardware note: device/host overlap buys wall clock only when the
"device" half does not compete with the host coders for the same execution
resources.  On a CPU-only jax backend with a single usable core (this is
recorded in the ``machine`` block) both halves share one core, so the
physical upper bound on speedup is ~1.0x and the >= 1.2x gate is enforced
only when ``usable_cores >= 2``.  Parity and overlap accounting are
hardware-independent and always gate.

    PYTHONPATH=src python benchmarks/bench_stream_overlap.py            # full
    PYTHONPATH=src python benchmarks/bench_stream_overlap.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

from repro.core import exec as exec_mod
from repro.core.pipeline import HierarchicalCompressor
from repro.runtime import archive_io
from repro.stream import stream_compress

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from bench_pipeline_throughput import s3d_workload, timed   # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload, 1 repeat, parity/overlap gate")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epochs-scale", type=float, default=0.1)
    ap.add_argument("--chunk-hyperblocks", type=int, default=16)
    ap.add_argument("--queue-depth", type=int, default=2)
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.repeats = 1
        # the smoke workload is only 16 hyper-blocks; narrow the stripes so
        # the pipeline actually has several chunks to overlap
        args.chunk_hyperblocks = min(args.chunk_hyperblocks, 4)

    cfg, hb = s3d_workload(args.smoke, args.seed, args.epochs_scale)
    print(f"workload: {hb.shape[0]} hyper-blocks of (k={hb.shape[1]}, "
          f"D={hb.shape[2]}) = {hb.size:,} values", file=sys.stderr)
    t0 = time.perf_counter()
    comp = HierarchicalCompressor(cfg).fit(hb, seed=args.seed)
    comp.fit_basis(hb)
    print(f"fit in {time.perf_counter() - t0:.1f}s", file=sys.stderr)

    tmpdir = tempfile.mkdtemp(prefix="bench_stream_")
    batch_path = os.path.join(tmpdir, "batch.rba")
    stream_path = os.path.join(tmpdir, "stream.rba")

    def batch_to_disk():
        archive = comp.compress(hb, tau=args.tau,
                                chunk_hyperblocks=args.chunk_hyperblocks)
        archive_io.write_archive(archive, batch_path)
        return archive

    def stream_to_disk():
        return stream_compress(comp, hb, tau=args.tau,
                               chunk_hyperblocks=args.chunk_hyperblocks,
                               out_path=stream_path,
                               queue_depth=args.queue_depth)

    # warmup both paths (jit traces, pools, page cache) before timing
    batch_archive = batch_to_disk()
    warm = stream_to_disk()
    traces_warm = exec_mod.total_retraces()

    exec_mod.reset_stage_stats()
    batch_s = timed(batch_to_disk, args.repeats)
    stream_s = timed(stream_to_disk, args.repeats)
    retrace_delta = exec_mod.total_retraces() - traces_warm

    # re-run once more for the stats record (timed() discards return values)
    result = stream_to_disk()
    stats = result.stats

    # -- parity: stream file == serialize_archive(batch archive) ------------
    with open(stream_path, "rb") as f:
        stream_bytes = f.read()
    with open(batch_path, "rb") as f:
        batch_bytes = f.read()
    batch_blob = archive_io.serialize_archive(batch_archive)
    parity_file = stream_bytes == batch_blob == batch_bytes
    parity_size = (batch_archive.compressed_bytes()
                   == result.archive.compressed_bytes()
                   == len(stream_bytes))
    usable = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    speedup = batch_s / stream_s if stream_s > 0 else 0.0

    out = {
        "workload": {"dataset": "s3d", "smoke": args.smoke,
                     "hyperblocks": int(hb.shape[0]), "k": int(hb.shape[1]),
                     "block_elems": int(hb.shape[2]),
                     "n_values": int(hb.size), "tau": args.tau,
                     "chunk_hyperblocks": args.chunk_hyperblocks,
                     "n_chunks": len(result.archive.chunks),
                     "queue_depth": args.queue_depth,
                     "repeats": args.repeats},
        "machine": {"cpu_count": os.cpu_count(), "usable_cores": usable,
                    "codec_workers": exec_mod.codec_workers(),
                    "jax_backend": __import__("jax").default_backend(),
                    "speedup_gate_enforced": usable >= 2},
        "batch": {"compress_plus_write_s": batch_s,
                  "values_per_s": hb.size / batch_s},
        "stream": {"compress_plus_write_s": stream_s,
                   "values_per_s": hb.size / stream_s,
                   "wall_s": stats.wall_s,
                   "busy_s": round(stats.busy_s, 4),
                   "overlap_s": round(stats.overlap_s, 4),
                   "overlap_efficiency": round(stats.overlap_efficiency(), 4),
                   "stage_busy_s": {k: round(v, 4) for k, v in
                                    sorted(stats.stage_busy_s.items())},
                   "queue_high_water": stats.queue_high_water,
                   "bytes_written": result.bytes_written},
        "parity": {"file_byte_identical": parity_file,
                   "compressed_bytes_equal": parity_size},
        "speedup_stream_vs_batch": round(speedup, 3),
        "retraces_after_warmup": int(retrace_delta),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"batch:  {batch_s:.3f}s  stream: {stream_s:.3f}s  "
          f"speedup {speedup:.2f}x")
    print(f"overlap: {stats.overlap_s:.3f}s busy "
          f"({stats.overlap_efficiency() * 100:.0f}% of wall) on "
          f"{usable} usable core(s)")
    print(f"parity: file identical={parity_file} sizes equal={parity_size}")
    print(f"written: {args.out}")

    ok = True
    if not (parity_file and parity_size):
        print("FAIL: stream/batch parity broken — chunk sections are not "
              "byte-identical", file=sys.stderr)
        ok = False
    if not stats.overlap_s > 0:
        print("FAIL: no measured device/host overlap", file=sys.stderr)
        ok = False
    if retrace_delta != 0:
        print(f"FAIL: {retrace_delta} retraces after warmup — streaming must "
              f"reuse the batch path's cached programs", file=sys.stderr)
        ok = False
    if usable >= 2 and speedup < 1.2:
        print(f"FAIL: speedup {speedup:.2f}x < 1.2x with {usable} usable "
              f"cores", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
