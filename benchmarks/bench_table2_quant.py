"""Paper Table II — latent-quantization sensitivity: quantize ONE autoencoder's
latent space at increasing bin sizes while the other stays unquantized, and
report the reconstruction error from the residual-BAE output.

Claim validated: the HBAE latent is MORE sensitive to quantization than the
BAE latent (its error grows faster with bin size) — coarse hyper-block
information is amplified by the decoder while the BAE only corrects residuals.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fitted_compressor
from repro.core import bae as bae_mod
from repro.core import hbae as hbae_mod
from repro.core.quantization import quantize_dequantize
from repro.data.blocks import nrmse

BINS = (0.005, 0.01, 0.05, 0.1, 0.5)


def _recon(comp, hb, hb_bin: float | None, bae_bin: float | None) -> np.ndarray:
    """Reconstruction with optional quantization of each latent stream."""
    lat = jax.jit(hbae_mod.hbae_encode)(comp.hbae_params, jnp.asarray(hb))
    if hb_bin:
        lat = quantize_dequantize(lat, hb_bin)
    y = np.asarray(jax.jit(hbae_mod.hbae_decode)(comp.hbae_params, lat))
    n, k, d = hb.shape
    resid = (hb - y).reshape(n * k, d)
    recon = y
    for p in comp.bae_params:
        lb = jax.jit(bae_mod.bae_encode)(p, jnp.asarray(resid))
        if bae_bin:
            lb = quantize_dequantize(lb, bae_bin)
        r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, lb))
        recon = recon + r_hat.reshape(n, k, d)
        resid = resid - r_hat
    return recon


def main(full: bool = False) -> None:
    datasets = ("s3d", "e3sm", "xgc") if full else ("s3d",)
    for ds in datasets:
        comp, hb = fitted_compressor(ds)
        for b in BINS:
            e_hb = nrmse(hb, _recon(comp, hb, hb_bin=b, bae_bin=None))
            e_bae = nrmse(hb, _recon(comp, hb, hb_bin=None, bae_bin=b))
            emit(f"table2.{ds}", bin=b, hbae_nrmse=float(e_hb),
                 bae_nrmse=float(e_bae))


if __name__ == "__main__":
    main()
