"""Shared benchmark plumbing: dataset/compressor caching (fits are reused
across sweeps within one benchmark run), CR/NRMSE evaluation, CSV emission."""
from __future__ import annotations

import dataclasses
import functools
import sys
import time

import numpy as np

from repro.core.options import CompressOptions
from repro.core.pipeline import HierarchicalCompressor
from repro.data import synthetic
from repro.data.blocks import nrmse


def emit(name: str, **fields) -> None:
    """One CSV line per result: name,key=value,..."""
    parts = [name] + [f"{k}={v}" for k, v in fields.items()]
    print(",".join(parts), flush=True)


@functools.lru_cache(maxsize=8)
def dataset(name: str, quick: bool = True, seed: int = 0):
    cfg, hb = synthetic.make_dataset(name, quick=quick, seed=seed)
    return cfg, hb


_FIT_CACHE: dict = {}


def fitted_compressor(name: str, *, hb_latent: int | None = None,
                      bae_latent: int | None = None,
                      use_attention: bool = True, use_bae: bool = True,
                      n_bae_stages: int = 1, quick: bool = True,
                      epochs: int | None = None,
                      seed: int = 0) -> tuple[HierarchicalCompressor, np.ndarray]:
    """Train (cached) a compressor variant on a synthetic dataset."""
    base_cfg, hb = dataset(name, quick, seed)
    cfg = dataclasses.replace(
        base_cfg,
        hb_latent=hb_latent or base_cfg.hb_latent,
        bae_latent=bae_latent or base_cfg.bae_latent,
        use_attention=use_attention, use_bae=use_bae,
        n_bae_stages=n_bae_stages,
        epochs_hbae=epochs or base_cfg.epochs_hbae,
        epochs_bae=epochs or base_cfg.epochs_bae)
    key = (name, cfg.hb_latent, cfg.bae_latent, use_attention, use_bae,
           n_bae_stages, quick, cfg.epochs_hbae, seed)
    if key not in _FIT_CACHE:
        t0 = time.time()
        comp = HierarchicalCompressor(cfg).fit(hb, seed=seed)
        _FIT_CACHE[key] = comp
        print(f"# fit {name} hb_latent={cfg.hb_latent} "
              f"bae_latent={cfg.bae_latent} attn={use_attention} "
              f"bae={use_bae}x{n_bae_stages} in {time.time() - t0:.1f}s",
              file=sys.stderr)
    return _FIT_CACHE[key], hb


def ae_point(comp: HierarchicalCompressor, hb: np.ndarray) -> dict:
    """AE-only CR/NRMSE (the paper's ablation points exclude GAE):
    tau=None = quantized+Huffman latents, no PCA stage."""
    archive = comp.compress(hb, options=CompressOptions(tau=None))
    recon = comp.decompress(archive)
    return {"cr": round(archive.compression_ratio(), 2),
            "nrmse": float(nrmse(hb, recon))}


def gae_point(comp: HierarchicalCompressor, hb: np.ndarray, tau: float) -> dict:
    archive = comp.compress(hb, options=CompressOptions(tau=tau))
    recon = comp.decompress(archive)
    return {"tau": tau, "cr": round(archive.compression_ratio(), 2),
            "nrmse": float(nrmse(hb, recon))}
