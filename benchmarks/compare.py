"""§Perf hillclimb helper: compare a tagged dry-run variant against the
baseline artifact for the same cell.

  python -m benchmarks.compare --cell qwen1.5-0.5b:train_4k:single --tag _sp
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import ARTIFACT_DIR, derive


def load_cell(cell: str, tag: str = "", artifact_dir: str = ARTIFACT_DIR) -> dict:
    arch, shape, mesh = cell.split(":")
    path = os.path.join(artifact_dir, f"{arch}__{shape}__{mesh}{tag}.json")
    with open(path) as f:
        return json.load(f)


def compare(cell: str, tag: str, artifact_dir: str = ARTIFACT_DIR) -> dict:
    base = derive(load_cell(cell, "", artifact_dir))
    var = derive(load_cell(cell, tag, artifact_dir))
    out = {"cell": cell, "tag": tag}
    for k in ("t_compute_s", "t_memory_s", "t_collective_s",
              "roofline_fraction", "useful_flops_ratio", "hbm_gb"):
        b, v = base[k], var[k]
        delta = (v - b) / b if b else float("inf")
        out[k] = {"base": b, "variant": v, "delta_pct": 100 * delta}
    out["dominant"] = {"base": base["dominant"], "variant": var["dominant"]}
    return out


def main(full: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    args, _ = ap.parse_known_args()
    r = compare(args.cell, args.tag, args.dir)
    print(f"cell {r['cell']}  tag {r['tag']}")
    print(f"dominant: {r['dominant']['base']} -> {r['dominant']['variant']}")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s",
              "roofline_fraction", "hbm_gb"):
        v = r[k]
        print(f"  {k:>18}: {v['base']:.4g} -> {v['variant']:.4g}  "
              f"({v['delta_pct']:+.1f}%)")


if __name__ == "__main__":
    main()
