"""HLO inspector for the §Perf loop: rank instructions by result-buffer size
and aggregate bytes by op kind — the 'profile' available without hardware
(DESIGN.md §8: the dry-run IR is the profile).

  REPRO_DUMP_HLO=/tmp/cell.hlo python -m repro.launch.dryrun --cell a:s:m
  python -m benchmarks.hlo_inspect /tmp/cell.hlo --top 25
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}
# "  %name = f32[8,128]{1,0} op-name(...)"
_LINE = re.compile(r"%\S+ = ([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+([a-z0-9\-]+)\(")


def shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def inspect(text: str, top: int = 25) -> tuple[list, dict]:
    rows = []
    by_kind: dict[str, int] = defaultdict(int)
    for m in _LINE.finditer(text):
        dtype, dims, op = m.groups()
        b = shape_bytes(dtype, dims)
        rows.append((b, op, f"{dtype}[{dims}]"))
        by_kind[op] += b
    rows.sort(reverse=True)
    return rows[:top], dict(sorted(by_kind.items(), key=lambda kv: -kv[1]))


def main(full: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=25)
    args, _ = ap.parse_known_args()
    with open(args.path) as f:
        text = f.read()
    rows, by_kind = inspect(text, args.top)
    print("== largest result buffers ==")
    for b, op, shape in rows:
        print(f"  {b / 1e6:10.1f} MB  {op:<22} {shape}")
    print("== total result bytes by op kind (top 20) ==")
    for op, b in list(by_kind.items())[:20]:
        print(f"  {b / 1e9:10.2f} GB  {op}")


if __name__ == "__main__":
    main()
