"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the artifact
JSONs.  ``python -m benchmarks.report [--dir artifacts/dryrun] [--tag X]``
prints markdown to stdout.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import ARTIFACT_DIR, derive


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def _fmt_t(s: float) -> str:
    if s < 1e-3:
        return f"{s * 1e6:.0f}us"
    if s < 1.0:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def load_all(artifact_dir: str, tag: str = "") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, f"*{tag}.json"))):
        base = os.path.basename(path)[:-5]
        if tag and not base.endswith(tag):
            continue
        if not tag and base.split("__")[-1] not in ("single", "multi"):
            continue   # skip tagged §Perf variants in the baseline table
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | compile | HBM/dev | HLO TFLOP/dev "
             "| coll MB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped | — | — | — | — |")
            continue
        mem = r["memory"]["peak_estimate_bytes"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f}s | {_fmt_bytes(mem)} | "
            f"{r['flops_per_device'] / 1e12:.2f} | "
            f"{r['collectives']['total_bytes'] / 1e6:.0f} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | t_comp | t_mem | t_coll | dominant | "
             "useful-FLOPs | roofline-frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") != "ok" or r["mesh"] != "single":
            continue
        d = derive(r)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {_fmt_t(d['t_compute_s'])} | "
            f"{_fmt_t(d['t_memory_s'])} | {_fmt_t(d['t_collective_s'])} | "
            f"**{d['dominant']}** | {d['useful_flops_ratio']:.3f} | "
            f"{d['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def collective_breakdown(recs: list[dict], arch: str, shape: str,
                         mesh: str = "single") -> str:
    for r in recs:
        if (r.get("arch"), r.get("shape"), r.get("mesh")) == (arch, shape, mesh):
            b = r["collectives"]["bytes"]
            c = r["collectives"]["counts"]
            return "; ".join(f"{k}: {c.get(k, 0)}x {_fmt_bytes(v)}"
                             for k, v in sorted(b.items()))
    return "(missing)"


def main(full: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--section", default="all",
                    choices=("all", "dryrun", "roofline"))
    args, _ = ap.parse_known_args()
    recs = load_all(args.dir, args.tag)
    if args.section in ("all", "dryrun"):
        print("### Dry-run\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single-pod)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
