"""Roofline derivation from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs  / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes  / (chips x 819 GB/s HBM)
    collective term = coll_bytes / (chips x 50 GB/s ICI link)

cost_analysis() reports post-SPMD per-partition numbers, so chips=1 in the
denominators here (the artifact's flops/bytes are already per device);
collective bytes are parsed from the partitioned HLO, which is likewise the
per-device program.  The dominant term is the bottleneck; MODEL_FLOPS /
(HLO_FLOPs x chips) is the useful-compute fraction (remat + padding +
non-matmul overhead show up here).  roofline_fraction = model-flops-time /
dominant-term-time — the score a perfect kernel on the dominant resource
would get.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
ICI_BW = 50e9               # B/s / link (per-device collective payload / this)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                            "dryrun")


def model_flops(rec: dict) -> float:
    """Kind-aware analytic FLOPs: 6·N_active·D for a train step (fwd+bwd),
    2·N_active·D for prefill/decode (fwd only).  Compressor cells keep the
    artifact's own analytic figure."""
    kind = rec.get("kind", "train")
    if kind == "compressor":
        return rec.get("model_flops", 0.0)
    n_active = rec.get("params", {}).get("active", 0)
    tokens = rec.get("tokens_per_step", 0)
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active * tokens


def derive(rec: dict) -> dict:
    n = rec["n_devices"]
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_per_device"]
    coll_dev = rec["collectives"]["total_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops_dev = model_flops(rec) / max(n, 1)
    useful = model_flops_dev / flops_dev if flops_dev > 0 else 0.0
    t_model = model_flops_dev / PEAK_FLOPS
    frac = t_model / max(terms[dominant], 1e-30)
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "useful_flops_ratio": useful, "roofline_fraction": frac,
            "hbm_gb": rec["memory"]["peak_estimate_bytes"] / 1e9}


def load(artifact_dir: str = ARTIFACT_DIR, mesh: str = "single",
         tag: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, f"*__{mesh}{tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        rows.append(derive(rec))
    return rows


def main(full: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=ARTIFACT_DIR)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args, _ = ap.parse_known_args()
    rows = load(args.dir, args.mesh, args.tag)
    if not rows:
        print(f"roofline,no_artifacts_found,dir={args.dir}")
        return
    hdr = ("arch", "shape", "dominant", "t_compute_s", "t_memory_s",
           "t_collective_s", "useful_flops_ratio", "roofline_fraction",
           "hbm_gb")
    print(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(",".join(
            f"{r[k]:.4g}" if isinstance(r[k], float) else str(r[k])
            for k in hdr))


if __name__ == "__main__":
    main()
