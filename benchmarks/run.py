"""Benchmark driver: one module per paper table/figure + system benches.

``python -m benchmarks.run``            — quick pass (CI-speed, all benches)
``python -m benchmarks.run --full``     — paper-scale sweeps
``python -m benchmarks.run --only fig6``

Output is CSV-ish lines ``name,key=value,...`` (see benchmarks/common.emit);
the roofline bench reads artifacts produced by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

BENCHES = (
    "bench_fig4_latent",      # paper Fig. 4
    "bench_fig5_components",  # paper Fig. 5
    "bench_table2_quant",     # paper Table II
    "bench_fig6_curves",      # paper Fig. 6 (vs sz-like / zfp-like)
    "bench_fig8_hist",        # paper Fig. 8
    "bench_fig9_species",     # paper Fig. 9
    "bench_kernels",          # Pallas kernels vs oracles
    "bench_grad_compress",    # technique on the DP collective
    "roofline",               # dry-run roofline table
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()

    failures = []
    for name in BENCHES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            mod.main(full=args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# --- {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
