"""Quickstart: compress a scientific field with a GUARANTEED error bound.

The paper's pipeline end-to-end on an S3D-like multi-species combustion field:
  1. block + hyper-block the data at the paper's geometry,
  2. fit the attention-based hyper-block autoencoder + residual block AE,
  3. compress with a user error bound tau (PCA-GAE post-processing),
  4. decompress and VERIFY every block satisfies ||x - x^G||_2 <= tau.

Runs on CPU in a few minutes:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.data import synthetic
from repro.data.blocks import nrmse
from repro.core.pipeline import HierarchicalCompressor

TAU = 0.5          # per-block l2 bound in the normalized domain

# 1. synthetic S3D-like data at the paper's block geometry (58 species,
#    blocks 58x5x4x4 flattened to 4640, hyper-blocks of k=10)
cfg, hyperblocks = synthetic.make_dataset("s3d", quick=True, seed=0)
print(f"data: {hyperblocks.shape[0]} hyper-blocks of "
      f"(k={hyperblocks.shape[1]}, D={hyperblocks.shape[2]})")

# 2. fit HBAE -> BAE (paper Sec. III-C schedule: Adam, lr=1e-3, MSE)
comp = HierarchicalCompressor(cfg).fit(hyperblocks, seed=0)

# 3. compress with the error-bound guarantee
archive = comp.compress(hyperblocks, tau=TAU)
print(f"compression ratio: {archive.compression_ratio():.1f}x "
      f"({archive.compressed_bytes():,} bytes for "
      f"{hyperblocks.nbytes:,} raw)")

# 4. decompress + verify the hard guarantee per GAE block
recon = comp.decompress(archive)
d_gae = cfg.gae_block_elems or cfg.block_elems
errs = np.linalg.norm(
    hyperblocks.reshape(-1, d_gae) - recon.reshape(-1, d_gae), axis=1)
print(f"NRMSE: {nrmse(hyperblocks, recon):.2e}")
print(f"max per-block l2 error: {errs.max():.4f}  (tau = {TAU})")
assert errs.max() <= TAU * (1 + 1e-5), "error-bound guarantee violated!"
print("guarantee holds for every block ✓")
