"""Batched serving with error-bounded KV-cache compression.

Spins up the serving engine on a small dense LM, runs a batch of requests
through continuous batching twice — once with the raw KV cache and once with
the bounded KV compressor (runtime/kvcache) — and reports:
  * agreement of generated tokens between the two runs,
  * the per-token KV perturbation bound that was enforced,
  * the storage the PCA-GAE page archive would use for the frozen pages.

Run:  PYTHONPATH=src python examples/serve_kvcompress.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models.registry import get_model, reduced_config
from repro.runtime.kvcache import PAGE_TOKENS, compress_pages, paginate
from repro.serve.engine import Request, ServeEngine

ARCH = "qwen3-1.7b"
KV_TAU = 0.05        # per-token l2 bound on the KV perturbation

cfg = reduced_config(get_config(ARCH))
run = RunConfig()
api = get_model(cfg)
params = api.init_params(jax.random.PRNGKey(0), cfg, run)

rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 24).astype(np.int32),
                max_new_tokens=12) for i in range(6)]

outs = {}
for tau in (None, KV_TAU):
    engine = ServeEngine(cfg, run, params, batch_size=3, max_len=64,
                         kv_tau=tau, seed=0)
    outs[tau] = engine.serve([Request(r.rid, r.prompt, r.max_new_tokens)
                              for r in reqs])

agree = np.mean([np.mean(a.tokens == b.tokens)
                 for a, b in zip(outs[None], outs[KV_TAU])])
print(f"token agreement raw-KV vs bounded-KV (tau={KV_TAU}): {agree:.1%}")

# what the PCA-GAE page archive costs for a frozen prompt cache
state = api.init_decode_state(params, cfg, run, 4, 64)
engine = ServeEngine(cfg, run, params, batch_size=4, max_len=64, seed=0)
prompts = rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32)
state, _ = engine._prefill(params, prompts, state)
k_cache = np.asarray(jax.tree.leaves(state.caches)[0])   # (L,B,S,KV,hd)
l, b, s, kvh, hd = k_cache.shape
pages = paginate(k_cache.reshape(l * b, s, kvh, hd))     # page = 16 tokens
flat = pages.reshape(-1, pages.shape[-1])
recon, store = compress_pages(flat, tau=0.1,
                              page_shape=(PAGE_TOKENS, kvh, hd))
errs = np.linalg.norm(flat - recon, axis=1)
print(f"frozen pages: {store.n_pages} pages, per-page l2 <= 0.1 "
      f"(max realized {errs.max():.4f})")
print(f"page archive: {store.nbytes():,} B vs {store.raw_nbytes():,} B raw "
      f"-> {store.raw_nbytes() / max(store.nbytes(), 1):.1f}x")
print("bounded KV compression ✓")
