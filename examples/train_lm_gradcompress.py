"""End-to-end LM training driver with the paper's technique on the gradient
all-reduce + full fault-tolerance plumbing.

Trains a ~100M-parameter qwen-family model (reduced depth/width preset for a
single host; pass --full-100m for the true 100M config if you have the
cores/accelerators) for a few hundred steps on the deterministic synthetic
token pipeline, with:
  * PCA+error-feedback gradient compression (rank-32 coefficients all-reduced
    instead of dense grads — runtime/grad_compress),
  * atomic checkpointing every 25 steps + automatic restore,
  * an INJECTED crash at step 30 to demonstrate the resilient runner
    recovering mid-run (watch for the [failure]/[restore] events).

Run:  PYTHONPATH=src python examples/train_lm_gradcompress.py --steps 120
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.tokens import SyntheticCorpus, TokenPipelineConfig
from repro.models.registry import reduced_config
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failures import ResilientRunner, chaos_wrap
from repro.train import optim
from repro.train.loop import init_train_state, make_train_step


def build_cfg(full_100m: bool):
    cfg = get_config("qwen1.5-0.5b")
    if full_100m:
        # ~100M params: 12L x d768 x ff2048, 32k vocab
        return dataclasses.replace(cfg, n_layers=12, d_model=768, n_heads=12,
                                   n_kv_heads=12, d_ff=2048, vocab=32768,
                                   head_dim=64)
    # single-host preset (~7M): same family, trains in minutes on CPU
    return dataclasses.replace(reduced_config(cfg), n_layers=4, d_model=128,
                               n_heads=4, n_kv_heads=4, d_ff=512, vocab=4096,
                               head_dim=32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--crash-at", type=int, default=30)
    args = ap.parse_args()

    cfg = build_cfg(args.full_100m)
    run = RunConfig(gradient_compression="pca_ef", grad_comp_rank=32)
    opt = optim.adamw(optim.warmup_cosine_schedule(1e-3, 20, args.steps),
                      max_grad_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {n_params/1e6:.1f}M params, grad compression rank 32")

    step_fn = jax.jit(make_train_step(cfg, run, opt), donate_argnums=(0,))

    corpus = SyntheticCorpus(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=7))

    def data_iter(start):
        def gen():
            s = start
            while True:
                b = corpus.batch_at(s)
                yield {k: jnp.asarray(v) for k, v in b.items()}
                s += 1
        return iter(gen())

    ckpt_dir = tempfile.mkdtemp(prefix="lm_ckpt_")
    ckpt = CheckpointManager(ckpt_dir, retention=2)

    # chaos: crash once at --crash-at to exercise restore-from-checkpoint
    chaotic = chaos_wrap(step_fn, {args.crash_at})
    runner = ResilientRunner(
        chaotic, ckpt, data_iter, save_every=25,
        on_event=lambda kind, info: print(f"[{kind}] {info}"))

    t0 = time.time()
    state, end = runner.run(state, 0, args.steps)
    dt = time.time() - t0
    print(f"\n{end} steps in {dt:.1f}s "
          f"({end * args.batch * args.seq / dt:,.0f} tok/s)")
    print(f"final loss {runner.stats.last_loss:.4f}  "
          f"restores={runner.stats.restores}  (ckpts in {ckpt_dir})")
    assert runner.stats.restores >= 1, "the injected crash should restore"
    assert runner.stats.last_loss < 8.0, "loss should be dropping"
    print("resilient compressed-gradient training ✓")


if __name__ == "__main__":
    main()
