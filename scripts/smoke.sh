#!/usr/bin/env bash
# Tier-1 smoke gate: hot-path lint, unit tests, an end-to-end compress ->
# container -> verify run, a seeded corruption-fuzz pass over the written
# archive, the throughput benchmark's retrace-regression gate, the
# stream-vs-batch parity gate, and the retrace-budget sweep.
# Everything here must stay green; run before merging.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

OUT="${TMPDIR:-/tmp}/smoke_archive.rba"

echo "== 1/7 hot-path jit lint =="
# Inline jax.jit() wrappers in core hot paths discard the trace cache and
# retrace per call — all jitted programs must go through core/exec.py's
# persistent cache (see docs/PERF.md).
if grep -rn 'jax\.jit(' src/repro/core/ src/repro/stream/ --include='*.py' \
        | grep -v 'core/exec\.py' \
        | grep -v 'functools\.partial(jax\.jit' \
        | grep -v '`' | grep -v '^[^:]*:[0-9]*: *#'; then
    echo "FAIL: inline jax.jit( call site in src/repro/ hot path" \
         "(route it through core/exec.py's JitCache)" >&2
    exit 1
fi

echo "== 2/7 unit tests =="
python -m pytest -x -q

echo "== 3/7 end-to-end compress + container verify =="
python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
    --epochs-scale 0.25 --chunk-hyperblocks 32 --out "$OUT" --verify

echo "== 4/7 corruption fuzz (seeded) =="
python -m repro.runtime.faultinject "$OUT" --trials 64 --seed 0

echo "== 5/7 throughput bench (smoke: retrace gate) =="
python benchmarks/bench_pipeline_throughput.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_pipeline_smoke.json"

echo "== 6/7 stream-vs-batch gate (byte-identical sections + overlap) =="
# Same input => the streamed container must be byte-identical to the batch
# serialization (identical payload sections AND identical compressed_bytes),
# with measured device/host overlap > 0.  See docs/STREAMING.md.
python benchmarks/bench_stream_overlap.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_stream_smoke.json"

echo "== 7/7 retrace-budget sweep =="
# Trace count over the (n_hyperblocks, bae_stages) sweep must equal the
# distinct-shape count — streaming adds zero traces over batch.
python benchmarks/bench_retrace_sweep.py

rm -f "$OUT"
echo "smoke OK"
