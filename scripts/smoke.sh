#!/usr/bin/env bash
# Tier-1 smoke gate: hot-path lint, exception-hygiene lint, options-surface
# lint, unit tests, an end-to-end compress -> container -> verify run, a
# seeded corruption-fuzz pass over the written archive, a seeded LIVE chaos
# gate over the streaming pipeline, the throughput benchmark's
# retrace-regression gate, the stream-vs-batch parity gate, the
# retrace-budget sweep, and the multi-device mesh parity gate.
# Everything here must stay green; run before merging.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

OUT="${TMPDIR:-/tmp}/smoke_archive.rba"

echo "== 1/11 hot-path jit lint =="
# Inline jax.jit() wrappers in core hot paths discard the trace cache and
# retrace per call — all jitted programs must go through core/exec.py's
# persistent cache (see docs/PERF.md).
if grep -rn 'jax\.jit(' src/repro/core/ src/repro/stream/ --include='*.py' \
        | grep -v 'core/exec\.py' \
        | grep -v 'functools\.partial(jax\.jit' \
        | grep -v '`' | grep -v '^[^:]*:[0-9]*: *#'; then
    echo "FAIL: inline jax.jit( call site in src/repro/ hot path" \
         "(route it through core/exec.py's JitCache)" >&2
    exit 1
fi

echo "== 2/11 stream exception-hygiene lint =="
# Broad excepts in the streaming pipeline swallow the typed fault-tolerance
# ladder (TransientStageError / deadline / quarantine).  The ONLY allowed
# broad-except sites are the designated retry boundaries, marked with a
# '# retry-boundary' comment on the except line.
if grep -rn -E 'except (BaseException|Exception)\b' src/repro/stream/ \
        --include='*.py' | grep -v '# retry-boundary'; then
    echo "FAIL: bare 'except Exception'/'except BaseException' in" \
         "src/repro/stream/ outside a designated '# retry-boundary'" >&2
    exit 1
fi

echo "== 3/11 options-surface lint =="
# The stage-program runners (run_compress_stage* / run_decompress_stage*)
# are internal to the pipeline: every external entry point must configure a
# compress through CompressOptions (core/options.py), not by calling the
# stage programs directly.  Allowed call sites: the exec module itself, the
# pipeline, the streaming scheduler, and the mesh executor/selfcheck.
if grep -rn -E 'run_(de)?compress_stage' src/repro/ --include='*.py' \
        | grep -v 'src/repro/core/exec\.py' \
        | grep -v 'src/repro/core/pipeline\.py' \
        | grep -v 'src/repro/stream/compress\.py' \
        | grep -v 'src/repro/parallel/mesh_exec\.py' \
        | grep -v 'src/repro/parallel/mesh_check\.py'; then
    echo "FAIL: stage-program call site outside the pipeline internals" \
         "(configure compression through repro.core.options.CompressOptions)" >&2
    exit 1
fi

echo "== 4/11 unit tests =="
python -m pytest -x -q

echo "== 5/11 end-to-end compress + container verify =="
python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
    --epochs-scale 0.25 --chunk-hyperblocks 32 --out "$OUT" --verify

echo "== 6/11 corruption fuzz (seeded) =="
python -m repro.runtime.faultinject "$OUT" --trials 64 --seed 0

echo "== 7/11 live chaos gate (seeded) =="
# Inject transient faults, poison stripes, and stage hangs into a running
# streaming pipeline; assert no deadlock, per-seed determinism, chunk
# byte-identity-or-lossless-fallback, and partial salvageability.
python -m repro.runtime.chaosinject --seed 0

echo "== 8/11 throughput bench (smoke: retrace gate) =="
python benchmarks/bench_pipeline_throughput.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_pipeline_smoke.json"

echo "== 9/11 stream-vs-batch gate (byte-identical sections + overlap) =="
# Same input => the streamed container must be byte-identical to the batch
# serialization (identical payload sections AND identical compressed_bytes),
# with measured device/host overlap > 0.  See docs/STREAMING.md.
python benchmarks/bench_stream_overlap.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_stream_smoke.json"

echo "== 10/11 retrace-budget sweep =="
# Trace count over the (n_hyperblocks, bae_stages) sweep must equal the
# distinct-shape count — streaming adds zero traces over batch.
python benchmarks/bench_retrace_sweep.py

echo "== 11/11 mesh parity gate (4 virtual devices, subprocess) =="
# Sharded-vs-single byte identity, psum-consistent PCA, zero retraces, and
# the dispatch-scaling gate, under XLA_FLAGS-forced virtual devices.  Runs
# in fresh subprocesses because the device count freezes at first jax
# import.  See docs/PERF.md (mesh sharding).
python -m repro.parallel.mesh_check > "${TMPDIR:-/tmp}/mesh_check.json" \
    || { cat "${TMPDIR:-/tmp}/mesh_check.json" >&2; exit 1; }
python benchmarks/bench_shard.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_shard_smoke.json"

rm -f "$OUT"
echo "smoke OK"
