#!/usr/bin/env bash
# Tier-1 smoke gate: unit tests, an end-to-end compress -> container ->
# verify run, and a seeded corruption-fuzz pass over the written archive.
# Everything here must stay green; run before merging.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

OUT="${TMPDIR:-/tmp}/smoke_archive.rba"

echo "== 1/3 unit tests =="
python -m pytest -x -q

echo "== 2/3 end-to-end compress + container verify =="
python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
    --epochs-scale 0.25 --chunk-hyperblocks 32 --out "$OUT" --verify

echo "== 3/3 corruption fuzz (seeded) =="
python -m repro.runtime.faultinject "$OUT" --trials 64 --seed 0

rm -f "$OUT"
echo "smoke OK"
