#!/usr/bin/env bash
# Tier-1 smoke gate: hot-path lint, exception-hygiene lint, unit tests, an
# end-to-end compress -> container -> verify run, a seeded corruption-fuzz
# pass over the written archive, a seeded LIVE chaos gate over the streaming
# pipeline, the throughput benchmark's retrace-regression gate, the
# stream-vs-batch parity gate, and the retrace-budget sweep.
# Everything here must stay green; run before merging.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

OUT="${TMPDIR:-/tmp}/smoke_archive.rba"

echo "== 1/9 hot-path jit lint =="
# Inline jax.jit() wrappers in core hot paths discard the trace cache and
# retrace per call — all jitted programs must go through core/exec.py's
# persistent cache (see docs/PERF.md).
if grep -rn 'jax\.jit(' src/repro/core/ src/repro/stream/ --include='*.py' \
        | grep -v 'core/exec\.py' \
        | grep -v 'functools\.partial(jax\.jit' \
        | grep -v '`' | grep -v '^[^:]*:[0-9]*: *#'; then
    echo "FAIL: inline jax.jit( call site in src/repro/ hot path" \
         "(route it through core/exec.py's JitCache)" >&2
    exit 1
fi

echo "== 2/9 stream exception-hygiene lint =="
# Broad excepts in the streaming pipeline swallow the typed fault-tolerance
# ladder (TransientStageError / deadline / quarantine).  The ONLY allowed
# broad-except sites are the designated retry boundaries, marked with a
# '# retry-boundary' comment on the except line.
if grep -rn -E 'except (BaseException|Exception)\b' src/repro/stream/ \
        --include='*.py' | grep -v '# retry-boundary'; then
    echo "FAIL: bare 'except Exception'/'except BaseException' in" \
         "src/repro/stream/ outside a designated '# retry-boundary'" >&2
    exit 1
fi

echo "== 3/9 unit tests =="
python -m pytest -x -q

echo "== 4/9 end-to-end compress + container verify =="
python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
    --epochs-scale 0.25 --chunk-hyperblocks 32 --out "$OUT" --verify

echo "== 5/9 corruption fuzz (seeded) =="
python -m repro.runtime.faultinject "$OUT" --trials 64 --seed 0

echo "== 6/9 live chaos gate (seeded) =="
# Inject transient faults, poison stripes, and stage hangs into a running
# streaming pipeline; assert no deadlock, per-seed determinism, chunk
# byte-identity-or-lossless-fallback, and partial salvageability.
python -m repro.runtime.chaosinject --seed 0

echo "== 7/9 throughput bench (smoke: retrace gate) =="
python benchmarks/bench_pipeline_throughput.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_pipeline_smoke.json"

echo "== 8/9 stream-vs-batch gate (byte-identical sections + overlap) =="
# Same input => the streamed container must be byte-identical to the batch
# serialization (identical payload sections AND identical compressed_bytes),
# with measured device/host overlap > 0.  See docs/STREAMING.md.
python benchmarks/bench_stream_overlap.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_stream_smoke.json"

echo "== 9/9 retrace-budget sweep =="
# Trace count over the (n_hyperblocks, bae_stages) sweep must equal the
# distinct-shape count — streaming adds zero traces over batch.
python benchmarks/bench_retrace_sweep.py

rm -f "$OUT"
echo "smoke OK"
