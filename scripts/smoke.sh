#!/usr/bin/env bash
# Tier-1 smoke gate: hot-path lint, unit tests, an end-to-end compress ->
# container -> verify run, a seeded corruption-fuzz pass over the written
# archive, and the throughput benchmark's retrace-regression gate.
# Everything here must stay green; run before merging.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src

OUT="${TMPDIR:-/tmp}/smoke_archive.rba"

echo "== 1/5 hot-path jit lint =="
# Inline jax.jit() wrappers in core hot paths discard the trace cache and
# retrace per call — all jitted programs must go through core/exec.py's
# persistent cache (see docs/PERF.md).
if grep -rn 'jax\.jit(' src/repro/core/ --include='*.py' \
        | grep -v 'core/exec\.py' \
        | grep -v 'functools\.partial(jax\.jit' \
        | grep -v '`' | grep -v '^[^:]*:[0-9]*: *#'; then
    echo "FAIL: inline jax.jit( call site in src/repro/core/ hot path" \
         "(route it through core/exec.py's JitCache)" >&2
    exit 1
fi

echo "== 2/5 unit tests =="
python -m pytest -x -q

echo "== 3/5 end-to-end compress + container verify =="
python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
    --epochs-scale 0.25 --chunk-hyperblocks 32 --out "$OUT" --verify

echo "== 4/5 corruption fuzz (seeded) =="
python -m repro.runtime.faultinject "$OUT" --trials 64 --seed 0

echo "== 5/5 throughput bench (smoke: retrace gate) =="
python benchmarks/bench_pipeline_throughput.py --smoke \
    --out "${TMPDIR:-/tmp}/BENCH_pipeline_smoke.json"

rm -f "$OUT"
echo "smoke OK"
