"""repro: attention-based hierarchical data reduction with guaranteed error
bounds (Li et al. 2024), built as a multi-pod JAX training/inference framework."""
__version__ = "1.0.0"
