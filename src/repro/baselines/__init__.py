from repro.baselines import block_ae, szlike, zfplike  # noqa: F401
