"""'Baseline' of the paper's ablation (Sec. III-D / Fig. 4-5).

A block-based compressor that divides data into blocks and compresses each
block independently with cascaded fully-connected layers (GBAE-style [16]) —
no hyper-blocks, no attention, no residual stage.  Latents are quantized +
Huffman coded with the same bitstream machinery as the main pipeline so the
comparison isolates the architecture, not the entropy coder.
"""
from __future__ import annotations

import dataclasses
import functools
import struct
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import codec as codec_mod
from repro.core import entropy
from repro.core.attention import linear, linear_init
from repro.core.errors import MalformedStream
from repro.core.quantization import dequantize, quantize
from repro.train import optim as optim_mod

_MAGIC = b"BAE1"

Array = jax.Array


def block_ae_init(key: Array, in_dim: int, hidden: int, latent: int,
                  depth: int = 2) -> dict:
    """Cascaded FC encoder/decoder: depth hidden layers each side."""
    keys = jax.random.split(key, 2 * depth + 2)
    enc, dims = [], [in_dim] + [hidden] * depth + [latent]
    for i in range(len(dims) - 1):
        enc.append(linear_init(keys[i], dims[i], dims[i + 1]))
    dec, dims_d = [], [latent] + [hidden] * depth + [in_dim]
    for i in range(len(dims_d) - 1):
        dec.append(linear_init(keys[depth + 1 + i], dims_d[i], dims_d[i + 1]))
    return {"enc": enc, "dec": dec}


def block_ae_encode(params: dict, x: Array) -> Array:
    h = x
    for i, p in enumerate(params["enc"]):
        h = linear(p, h)
        if i < len(params["enc"]) - 1:
            h = jax.nn.relu(h)
    return h


def block_ae_decode(params: dict, z: Array) -> Array:
    h = z
    for i, p in enumerate(params["dec"]):
        h = linear(p, h)
        if i < len(params["dec"]) - 1:
            h = jax.nn.relu(h)
    return h


def block_ae_apply(params: dict, x: Array) -> Array:
    return block_ae_decode(params, block_ae_encode(params, x))


def _loss(params, x):
    return jnp.mean(jnp.square(block_ae_apply(params, x) - x))


@functools.partial(jax.jit, static_argnames=("opt",), donate_argnums=(0, 1))
def _step(params, opt_state, x, opt):
    loss, grads = jax.value_and_grad(_loss)(params, x)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss


@dataclasses.dataclass
class BlockAEBaseline:
    """fit/compress on (N, D) flattened blocks."""
    in_dim: int
    hidden: int = 256
    latent: int = 32
    depth: int = 2
    bin_size: float = 0.005
    epochs: int = 30
    batch: int = 256
    lr: float = 1e-3
    params: Optional[dict] = None

    def fit(self, blocks: np.ndarray, seed: int = 0) -> "BlockAEBaseline":
        n, d = blocks.shape
        assert d == self.in_dim
        self.params = block_ae_init(jax.random.PRNGKey(seed), d, self.hidden,
                                    self.latent, self.depth)
        opt = optim_mod.adam(lr=self.lr)
        opt_state = opt.init(self.params)
        rng = np.random.default_rng(seed)
        data = jnp.asarray(blocks)
        b = min(self.batch, n)
        for _ in range(self.epochs):
            order = rng.permutation(n)
            for i in range(0, n - b + 1, b):
                self.params, opt_state, _ = _step(self.params, opt_state,
                                                  data[order[i:i + b]], opt)
        return self

    def compress(self, blocks: np.ndarray, quantize_latent: bool = True
                 ) -> tuple[np.ndarray, int]:
        """Returns (reconstruction, compressed_bytes)."""
        if quantize_latent:
            c = self.codec()
            enc = c.compress(blocks, self.bin_size)
            return c.decompress(enc), enc.nbytes
        z = np.asarray(jax.jit(block_ae_encode)(self.params, jnp.asarray(blocks)))
        nbytes = z.size * 4
        recon = np.asarray(jax.jit(block_ae_decode)(self.params, jnp.asarray(z)))
        return recon, nbytes

    def codec(self) -> "BlockAECodec":
        """Unified-protocol view of this fitted baseline (model cost is
        carried by the codec object, like the main pipeline's weights)."""
        if self.params is None:
            raise ValueError("BlockAEBaseline.codec(): call fit() first")
        return BlockAECodec(baseline=self)


@dataclasses.dataclass(frozen=True)
class BlockAECodec:
    """``Codec``-protocol adapter over a fitted :class:`BlockAEBaseline`.

    ``bound`` is the latent quantization bin size; the payload ships the
    quantized latents (header + Huffman stream) and ``decompress`` runs
    dequantize + the decoder network — so it only decodes payloads produced
    with the SAME fitted weights.
    """
    baseline: BlockAEBaseline
    name: str = "block-ae"

    def compress(self, data: np.ndarray, bound: float) -> codec_mod.Encoded:
        bin_size = float(bound)
        if not bin_size > 0:
            raise ValueError(f"block-ae bin size must be > 0, got {bin_size}")
        z = jax.jit(block_ae_encode)(self.baseline.params, jnp.asarray(data))
        q = np.asarray(quantize(z, bin_size))
        from repro.runtime import archive_io
        stream = entropy.huffman_compress(q.ravel()) if q.size else None
        head = _MAGIC + struct.pack("<QId", q.shape[0], q.shape[1], bin_size)
        return codec_mod.Encoded(
            codec=self.name, payload=head + archive_io._pack_stream(stream))

    def decompress(self, enc: codec_mod.Encoded) -> np.ndarray:
        from repro.runtime import archive_io
        r = archive_io._Reader(enc.payload, "block-ae payload")
        if r.take(4) != _MAGIC:
            raise MalformedStream("block-ae payload: bad magic")
        n, latent, bin_size = struct.unpack("<QId", r.take(20))
        if latent != self.baseline.latent:
            raise MalformedStream(
                f"block-ae payload has latent dim {latent}, this codec's "
                f"model expects {self.baseline.latent}")
        if not bin_size > 0:
            raise MalformedStream(
                f"block-ae payload: bad bin size {bin_size}")
        stream = archive_io._unpack_stream(r)
        q = (entropy.huffman_decompress(stream) if stream is not None
             else np.zeros(0, np.int64))
        if q.size != n * latent:
            raise MalformedStream(
                f"block-ae stream has {q.size} latents, expected "
                f"{n * latent}")
        z = dequantize(jnp.asarray(q.reshape(n, latent)), bin_size)
        return np.asarray(jax.jit(block_ae_decode)(self.baseline.params, z))
