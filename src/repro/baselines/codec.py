"""Unified baseline codec surface.

Before this module each reference compressor exposed its own ad-hoc shape:
``szlike.compress(data, eb) -> (decoded, nbytes)`` (with ``nbytes`` an
*estimate* — header cost was a hard-coded fudge and nothing could actually
decode), ``zfplike`` the same, and ``BlockAEBaseline.compress`` a third
variant.  The :class:`Codec` protocol replaces the estimates with the real
thing:

* ``compress(data, bound) -> Encoded`` — a self-contained opaque payload;
  ``Encoded.nbytes`` is ``len(payload)``, the honest storage cost of
  something that can genuinely be decoded, not an accounting guess.
* ``decompress(enc) -> np.ndarray`` — decodes the payload alone (plus
  whatever model state the codec object itself carries, e.g. the block-AE
  weights — mirroring how the main pipeline ships model cost separately).

``compression_curve`` is the one CR/NRMSE sweep implementation every
benchmark uses; it round-trips through ``decompress`` so a curve can never
quote a ratio for bytes that don't decode.

The legacy module-level ``compress(data, bound) -> (decoded, nbytes)``
functions remain as thin delegates so existing callers keep working.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class Encoded:
    """One baseline compression result: an opaque, self-describing payload.

    ``payload`` contains everything the producing codec needs to decode
    (header, shapes, bounds, entropy streams) — pass it back to the SAME
    codec's ``decompress``.
    """
    codec: str          # name of the codec that produced it
    payload: bytes

    @property
    def nbytes(self) -> int:
        return len(self.payload)


@runtime_checkable
class Codec(Protocol):
    """The one surface every baseline compressor speaks."""
    name: str

    def compress(self, data: np.ndarray, bound: float) -> Encoded:
        """Encode ``data`` under the codec's error/size knob ``bound``."""
        ...

    def decompress(self, enc: Encoded) -> np.ndarray:
        """Decode a payload this codec produced back to an array."""
        ...


def roundtrip(codec: Codec, data: np.ndarray, bound: float
              ) -> tuple[np.ndarray, Encoded]:
    """Compress + decompress in one call: ``(decoded, enc)``."""
    enc = codec.compress(data, bound)
    return codec.decompress(enc), enc


def compression_curve(codec: Codec, data: np.ndarray,
                      bounds: Sequence[float], bound_key: str = "eb"
                      ) -> list[dict]:
    """CR / NRMSE points for a sweep of ``bounds``, computed from the REAL
    decoded payloads (every quoted ratio is for bytes that decode)."""
    from repro.data.blocks import nrmse
    out = []
    for b in bounds:
        dec, enc = roundtrip(codec, data, b)
        out.append({bound_key: b, "cr": data.size * 4 / enc.nbytes,
                    "nrmse": float(nrmse(data, dec))})
    return out
