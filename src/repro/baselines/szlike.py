"""SZ3-mechanism reference compressor ("sz-like").

Implements the interpolation-based predictor that powers SZ3 (Zhao et al.;
[4] in the paper): a multi-level scheme where each level predicts midpoints by
linear interpolation of already-*decoded* coarser points, quantizes the
prediction error with bins of width 2*eb (guaranteeing pointwise |err| <= eb),
Huffman-codes the quantization integers and DEFLATEs the seed.  The classic
pointwise Lorenzo loop is inherently serial; the interpolation form is
level-sequential but fully vectorized within a level, so it runs at numpy
speed while keeping the same error-control mechanism.

This is a faithful *mechanism* reimplementation for comparison curves, not the
tuned C++ SZ3 codebase (see DESIGN.md §1); EXPERIMENTS.md labels it "sz-like".
"""
from __future__ import annotations

import numpy as np

from repro.core import entropy


def compress(data: np.ndarray, eb: float) -> tuple[np.ndarray, int]:
    """Error-bounded compression. Returns (decoded, compressed_bytes).

    Pointwise guarantee: |data - decoded| <= eb (quantized-midpoint residuals;
    the coarsest seed grid is stored exactly).
    """
    x = np.asarray(data, np.float32)
    nd = x.ndim
    dec = np.zeros_like(x)

    max_stride = 1
    for n in x.shape:
        while max_stride * 2 < n:
            max_stride *= 2

    seed_slices = tuple(slice(None, None, max_stride) for _ in range(nd))
    seed = x[seed_slices].copy()
    dec[seed_slices] = seed

    quants: list[np.ndarray] = []
    stride = max_stride
    while stride >= 2:
        half = stride // 2
        for a in range(nd):
            n = x.shape[a]
            targets = np.arange(half, n, stride)
            if targets.size == 0:
                continue
            # grid of already-decoded points: axes before `a` refined to
            # `half` by earlier passes of this level, axes after still `stride`
            grid = tuple(slice(None, None, half) if i < a else
                         (slice(None) if i == a else slice(None, None, stride))
                         for i in range(nd))
            sub_dec = dec[grid]          # strided view — writes propagate
            sub_x = x[grid]
            left = targets - half
            last = ((n - 1) // stride) * stride
            right = np.minimum(targets + half, last)
            dl = np.take(sub_dec, left, axis=a)
            dr = np.take(sub_dec, right, axis=a)
            pred = 0.5 * (dl + dr)
            err = np.take(sub_x, targets, axis=a) - pred
            q = np.round(err / (2.0 * eb)).astype(np.int64)
            quants.append(q.ravel())
            vals = pred + q.astype(np.float32) * (2.0 * eb)
            idx = tuple(slice(None) if i != a else targets for i in range(nd))
            sub_dec[idx] = vals
        stride = half

    allq = np.concatenate(quants) if quants else np.zeros(0, np.int64)
    stream_bytes = entropy.huffman_compress(allq).nbytes() if allq.size else 0
    seed_bytes = len(entropy.zlib_pack(seed.tobytes()))
    total = stream_bytes + seed_bytes + 64
    return dec, total


def compression_curve(data: np.ndarray, ebs: list[float]) -> list[dict]:
    """CR / NRMSE points for a sweep of error bounds."""
    from repro.data.blocks import nrmse
    out = []
    for eb in ebs:
        dec, nbytes = compress(data, eb)
        out.append({"eb": eb, "cr": data.size * 4 / nbytes,
                    "nrmse": nrmse(data, dec)})
    return out
