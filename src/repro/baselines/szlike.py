"""SZ3-mechanism reference compressor ("sz-like").

Implements the interpolation-based predictor that powers SZ3 (Zhao et al.;
[4] in the paper): a multi-level scheme where each level predicts midpoints by
linear interpolation of already-*decoded* coarser points, quantizes the
prediction error with bins of width 2*eb (guaranteeing pointwise |err| <= eb),
Huffman-codes the quantization integers and DEFLATEs the seed.  The classic
pointwise Lorenzo loop is inherently serial; the interpolation form is
level-sequential but fully vectorized within a level, so it runs at numpy
speed while keeping the same error-control mechanism.

``SZLikeCodec`` speaks the unified :mod:`repro.baselines.codec` protocol: the
payload is a real decodable bitstream (header + DEFLATE seed + Huffman
quants) and ``decompress`` replays the interpolation schedule from decoded
points only — the decoder touches nothing the encoder didn't ship.

This is a faithful *mechanism* reimplementation for comparison curves, not the
tuned C++ SZ3 codebase (see DESIGN.md §1); EXPERIMENTS.md labels it "sz-like".
"""
from __future__ import annotations

import struct

import numpy as np

from repro.baselines import codec as codec_mod
from repro.core import entropy
from repro.core.errors import MalformedStream

_MAGIC = b"SZL1"
_MAX_DIMS = 8


def _max_stride(shape: tuple) -> int:
    ms = 1
    for n in shape:
        while ms * 2 < n:
            ms *= 2
    return ms


def _schedule(shape: tuple, dec: np.ndarray, consume):
    """Run the level-sequential interpolation schedule over ``dec``.

    ``consume(pred, a, targets, grid_axis_view)`` is called once per
    (stride, axis) pass with the midpoint predictions; it must return the
    quantization integers for that pass (the encoder computes them from the
    original data, the decoder reads them off the entropy stream).  ``dec``
    is refined in place — both sides therefore predict from IDENTICAL
    decoded values, which is what makes the scheme error-bounded and the
    decode bit-exact.
    """
    nd = len(shape)
    stride = _max_stride(shape)
    while stride >= 2:
        half = stride // 2
        for a in range(nd):
            n = shape[a]
            targets = np.arange(half, n, stride)
            if targets.size == 0:
                continue
            # grid of already-decoded points: axes before `a` refined to
            # `half` by earlier passes of this level, axes after still
            # `stride`
            grid = tuple(slice(None, None, half) if i < a else
                         (slice(None) if i == a else slice(None, None, stride))
                         for i in range(nd))
            sub_dec = dec[grid]          # strided view — writes propagate
            left = targets - half
            last = ((n - 1) // stride) * stride
            right = np.minimum(targets + half, last)
            dl = np.take(sub_dec, left, axis=a)
            dr = np.take(sub_dec, right, axis=a)
            pred = 0.5 * (dl + dr)
            q = consume(pred, a, targets, grid)
            vals = pred + q.astype(np.float32) * _2EB
            idx = tuple(slice(None) if i != a else targets for i in range(nd))
            sub_dec[idx] = vals
        stride = half


class SZLikeCodec:
    """Error-bounded interpolation codec (unified ``Codec`` protocol)."""

    name = "sz-like"

    def compress(self, data: np.ndarray, bound: float) -> codec_mod.Encoded:
        dec, quants, seed = _encode(np.asarray(data, np.float32),
                                    float(bound))
        return codec_mod.Encoded(codec=self.name,
                                 payload=_pack(data.shape, float(bound),
                                               seed, quants))

    def decompress(self, enc: codec_mod.Encoded) -> np.ndarray:
        shape, eb, seed, allq = _unpack(enc.payload)
        return _decode(shape, eb, seed, allq)


# _schedule closes over the bin width via this module-level slot so encoder
# and decoder run the exact same `pred + q * _2EB` expression (bit-equal).
_2EB = 0.0


def _encode(x: np.ndarray, eb: float
            ) -> tuple[np.ndarray, list[np.ndarray], np.ndarray]:
    global _2EB
    dec = np.zeros_like(x)
    ms = _max_stride(x.shape)
    seed_slices = tuple(slice(None, None, ms) for _ in range(x.ndim))
    seed = x[seed_slices].copy()
    dec[seed_slices] = seed
    quants: list[np.ndarray] = []
    _2EB = 2.0 * eb

    def consume(pred, a, targets, grid):
        err = np.take(x[grid], targets, axis=a) - pred
        q = np.round(err / (2.0 * eb)).astype(np.int64)
        quants.append(q.ravel())
        return q

    _schedule(x.shape, dec, consume)
    return dec, quants, seed


def _decode(shape: tuple, eb: float, seed: np.ndarray,
            allq: np.ndarray) -> np.ndarray:
    global _2EB
    dec = np.zeros(shape, np.float32)
    ms = _max_stride(shape)
    dec[tuple(slice(None, None, ms) for _ in range(len(shape)))] = seed
    _2EB = 2.0 * eb
    pos = [0]

    def consume(pred, a, targets, grid):
        n = int(np.prod(pred.shape))
        if pos[0] + n > allq.size:
            raise MalformedStream(
                f"sz-like stream exhausted: need {n} quants at {pos[0]}, "
                f"have {allq.size}")
        q = allq[pos[0]:pos[0] + n].reshape(pred.shape)
        pos[0] += n
        return q

    _schedule(shape, dec, consume)
    if pos[0] != allq.size:
        raise MalformedStream(
            f"sz-like stream has {allq.size} quants, schedule consumed "
            f"{pos[0]}")
    return dec


def _pack(shape: tuple, eb: float, seed: np.ndarray,
          quants: list[np.ndarray]) -> bytes:
    from repro.runtime import archive_io
    allq = (np.concatenate(quants) if quants else np.zeros(0, np.int64))
    stream = entropy.huffman_compress(allq) if allq.size else None
    seed_blob = entropy.zlib_pack(np.ascontiguousarray(seed, "<f4").tobytes())
    head = _MAGIC + struct.pack("<B", len(shape))
    head += struct.pack(f"<{len(shape)}I", *shape)
    head += struct.pack("<dQ", eb, len(seed_blob))
    return head + seed_blob + archive_io._pack_stream(stream)


def _unpack(payload: bytes) -> tuple[tuple, float, np.ndarray, np.ndarray]:
    from repro.runtime import archive_io
    r = archive_io._Reader(payload, "sz-like payload")
    if r.take(4) != _MAGIC:
        raise MalformedStream("sz-like payload: bad magic")
    nd = r.u8()
    if not 1 <= nd <= _MAX_DIMS:
        raise MalformedStream(f"sz-like payload: absurd rank {nd}")
    shape = struct.unpack(f"<{nd}I", r.take(4 * nd))
    eb, seed_len = struct.unpack("<dQ", r.take(16))
    if not eb > 0:
        raise MalformedStream(f"sz-like payload: bad error bound {eb}")
    seed_raw = entropy.zlib_unpack(r.take(seed_len))
    ms = _max_stride(shape)
    seed_shape = tuple((n + ms - 1) // ms for n in shape)
    want = int(np.prod(seed_shape)) * 4
    if len(seed_raw) != want:
        raise MalformedStream(
            f"sz-like seed holds {len(seed_raw)} bytes, expected {want}")
    seed = np.frombuffer(seed_raw, "<f4").reshape(seed_shape)
    stream = archive_io._unpack_stream(r)
    allq = (entropy.huffman_decompress(stream) if stream is not None
            else np.zeros(0, np.int64))
    return shape, eb, seed, allq


# -- legacy module-level surface --------------------------------------------

def compress(data: np.ndarray, eb: float) -> tuple[np.ndarray, int]:
    """Error-bounded compression. Returns (decoded, compressed_bytes).

    Pointwise guarantee: |data - decoded| <= eb (quantized-midpoint residuals;
    the coarsest seed grid is stored exactly).  ``compressed_bytes`` is the
    length of the REAL decodable payload (``SZLikeCodec``), not an estimate.
    """
    x = np.asarray(data, np.float32)
    dec, quants, seed = _encode(x, float(eb))
    return dec, len(_pack(x.shape, float(eb), seed, quants))


def compression_curve(data: np.ndarray, ebs: list[float]) -> list[dict]:
    """CR / NRMSE points for a sweep of error bounds."""
    return codec_mod.compression_curve(SZLikeCodec(), data, ebs,
                                       bound_key="eb")
