"""ZFP-mechanism reference compressor ("zfp-like").

Implements the ZFP pipeline ([15][17] in the paper) on 4^d blocks:
block-floating-point exponent alignment -> ZFP's near-orthogonal separable
decorrelating transform -> uniform coefficient quantization (precision derived
from the requested tolerance) -> Huffman + DEFLATE.  Embedded bit-plane group
testing is replaced by entropy coding of quantized coefficients — same
transform-coding mechanism, simpler bitstream (see DESIGN.md §1);
EXPERIMENTS.md labels it "zfp-like".
"""
from __future__ import annotations

import numpy as np

from repro.core import entropy

# ZFP's forward decorrelating transform (Lindstrom 2014), rows = basis
_T = np.array([[4, 4, 4, 4],
               [5, 1, -1, -5],
               [-4, 4, 4, -4],
               [-2, 6, -6, 2]], np.float32) / 16.0
_TI = np.linalg.inv(_T)


def _blockify(x: np.ndarray) -> tuple[np.ndarray, tuple, tuple]:
    """Pad each dim to a multiple of 4 and split into (n_blocks, 4, 4, ...)."""
    nd = x.ndim
    pads = [(0, (-s) % 4) for s in x.shape]
    xp = np.pad(x, pads, mode="edge")
    grid = tuple(s // 4 for s in xp.shape)
    inter = []
    for g in grid:
        inter.extend([g, 4])
    y = xp.reshape(inter).transpose(*range(0, 2 * nd, 2), *range(1, 2 * nd, 2))
    return y.reshape(int(np.prod(grid)), *([4] * nd)), xp.shape, grid


def _unblockify(blocks: np.ndarray, padded_shape: tuple, grid: tuple,
                orig_shape: tuple) -> np.ndarray:
    nd = len(grid)
    y = blocks.reshape(*grid, *([4] * nd))
    perm = []
    for i in range(nd):
        perm.extend([i, nd + i])
    xp = y.transpose(*perm).reshape(padded_shape)
    return xp[tuple(slice(0, s) for s in orig_shape)]


def _transform(blocks: np.ndarray, mat: np.ndarray) -> np.ndarray:
    """Separable transform along every block axis (axes 1..nd)."""
    out = blocks
    nd = blocks.ndim - 1
    for a in range(1, nd + 1):
        out = np.moveaxis(np.tensordot(mat, np.moveaxis(out, a, 0), axes=(1, 0)), 0, a)
    return out


def compress(data: np.ndarray, tol: float) -> tuple[np.ndarray, int]:
    """Tolerance-targeted compression. Returns (decoded, compressed_bytes)."""
    x = np.asarray(data, np.float32)
    blocks, padded_shape, grid = _blockify(x)
    nb = blocks.shape[0]
    flatb = blocks.reshape(nb, -1)

    # block-floating-point: per-block power-of-two scale
    emax = np.maximum(np.abs(flatb).max(axis=1), 1e-30)
    scale = np.exp2(np.ceil(np.log2(emax)))[:, None]
    normed = (flatb / scale).reshape(blocks.shape)

    coeffs = _transform(normed, _T)
    # uniform quantization of transform coefficients; step tuned so the
    # per-point reconstruction error lands near `tol` (transform gain ~1)
    step = tol * 2.0
    q = np.round(coeffs.reshape(nb, -1) / (step / scale)).astype(np.int64)
    deq = q.astype(np.float32) * (step / scale)

    rec = _transform(deq.reshape(blocks.shape), _TI)
    rec_blocks = rec.reshape(nb, -1) * scale
    decoded = _unblockify(rec_blocks.reshape(blocks.shape), padded_shape, grid, x.shape)

    stream = entropy.huffman_compress(q)
    scale_bytes = len(entropy.zlib_pack(np.log2(scale[:, 0]).astype(np.int8).tobytes()))
    total = stream.nbytes() + scale_bytes + 64
    return decoded.astype(np.float32), total


def compression_curve(data: np.ndarray, tols: list[float]) -> list[dict]:
    from repro.data.blocks import nrmse
    out = []
    for tol in tols:
        dec, nbytes = compress(data, tol)
        out.append({"tol": tol, "cr": data.size * 4 / nbytes,
                    "nrmse": nrmse(data, dec)})
    return out
