"""ZFP-mechanism reference compressor ("zfp-like").

Implements the ZFP pipeline ([15][17] in the paper) on 4^d blocks:
block-floating-point exponent alignment -> ZFP's near-orthogonal separable
decorrelating transform -> uniform coefficient quantization (precision derived
from the requested tolerance) -> Huffman + DEFLATE.  Embedded bit-plane group
testing is replaced by entropy coding of quantized coefficients — same
transform-coding mechanism, simpler bitstream (see DESIGN.md §1);
EXPERIMENTS.md labels it "zfp-like".

``ZFPLikeCodec`` speaks the unified :mod:`repro.baselines.codec` protocol:
the payload (header + DEFLATE per-block scale exponents + Huffman coefficient
stream) is fully self-describing, and ``decompress`` rebuilds ``deq = q *
(step / scale)`` from shipped integers exactly as the encoder computed it —
decode is bit-identical to the encoder-side reconstruction.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.baselines import codec as codec_mod
from repro.core import entropy
from repro.core.errors import MalformedStream

_MAGIC = b"ZFL1"
_MAX_DIMS = 8

# ZFP's forward decorrelating transform (Lindstrom 2014), rows = basis
_T = np.array([[4, 4, 4, 4],
               [5, 1, -1, -5],
               [-4, 4, 4, -4],
               [-2, 6, -6, 2]], np.float32) / 16.0
_TI = np.linalg.inv(_T)


def _blockify(x: np.ndarray) -> tuple[np.ndarray, tuple, tuple]:
    """Pad each dim to a multiple of 4 and split into (n_blocks, 4, 4, ...)."""
    nd = x.ndim
    pads = [(0, (-s) % 4) for s in x.shape]
    xp = np.pad(x, pads, mode="edge")
    grid = tuple(s // 4 for s in xp.shape)
    inter = []
    for g in grid:
        inter.extend([g, 4])
    y = xp.reshape(inter).transpose(*range(0, 2 * nd, 2), *range(1, 2 * nd, 2))
    return y.reshape(int(np.prod(grid)), *([4] * nd)), xp.shape, grid


def _unblockify(blocks: np.ndarray, padded_shape: tuple, grid: tuple,
                orig_shape: tuple) -> np.ndarray:
    nd = len(grid)
    y = blocks.reshape(*grid, *([4] * nd))
    perm = []
    for i in range(nd):
        perm.extend([i, nd + i])
    xp = y.transpose(*perm).reshape(padded_shape)
    return xp[tuple(slice(0, s) for s in orig_shape)]


def _transform(blocks: np.ndarray, mat: np.ndarray) -> np.ndarray:
    """Separable transform along every block axis (axes 1..nd)."""
    out = blocks
    nd = blocks.ndim - 1
    for a in range(1, nd + 1):
        out = np.moveaxis(np.tensordot(mat, np.moveaxis(out, a, 0), axes=(1, 0)), 0, a)
    return out


def _reconstruct(q: np.ndarray, log2_scale: np.ndarray, tol: float,
                 shape: tuple) -> np.ndarray:
    """Shared decoder core: quant integers + scale exponents -> array.

    Encoder and decoder both call this, so the encoder's returned ``decoded``
    IS the decode of the payload, bit for bit.
    """
    nd = len(shape)
    grid = tuple((s + 3) // 4 for s in shape)
    padded_shape = tuple(g * 4 for g in grid)
    nb = int(np.prod(grid))
    block_shape = (nb, *([4] * nd))
    scale = np.exp2(log2_scale.astype(np.float32))[:, None]
    step = tol * 2.0
    deq = q.astype(np.float32) * (step / scale)
    rec = _transform(deq.reshape(block_shape), _TI)
    rec_blocks = rec.reshape(nb, -1) * scale
    return _unblockify(rec_blocks.reshape(block_shape), padded_shape, grid,
                       shape).astype(np.float32)


class ZFPLikeCodec:
    """Transform-coding codec (unified ``Codec`` protocol)."""

    name = "zfp-like"

    def compress(self, data: np.ndarray, bound: float) -> codec_mod.Encoded:
        x = np.asarray(data, np.float32)
        tol = float(bound)
        blocks, _padded, _grid = _blockify(x)
        nb = blocks.shape[0]
        flatb = blocks.reshape(nb, -1)

        # block-floating-point: per-block power-of-two scale
        emax = np.maximum(np.abs(flatb).max(axis=1), 1e-30)
        log2_scale = np.ceil(np.log2(emax)).astype(np.int8)
        scale = np.exp2(log2_scale.astype(np.float32))[:, None]
        normed = (flatb / scale).reshape(blocks.shape)

        coeffs = _transform(normed, _T)
        # uniform quantization of transform coefficients; step tuned so the
        # per-point reconstruction error lands near `tol` (transform gain ~1)
        step = tol * 2.0
        q = np.round(coeffs.reshape(nb, -1) / (step / scale)).astype(np.int64)
        return codec_mod.Encoded(codec=self.name,
                                 payload=_pack(x.shape, tol, log2_scale, q))

    def decompress(self, enc: codec_mod.Encoded) -> np.ndarray:
        shape, tol, log2_scale, q = _unpack(enc.payload)
        return _reconstruct(q, log2_scale, tol, shape)


def _pack(shape: tuple, tol: float, log2_scale: np.ndarray,
          q: np.ndarray) -> bytes:
    from repro.runtime import archive_io
    stream = entropy.huffman_compress(q.ravel()) if q.size else None
    scale_blob = entropy.zlib_pack(log2_scale.tobytes())
    head = _MAGIC + struct.pack("<B", len(shape))
    head += struct.pack(f"<{len(shape)}I", *shape)
    head += struct.pack("<dQ", tol, len(scale_blob))
    return head + scale_blob + archive_io._pack_stream(stream)


def _unpack(payload: bytes) -> tuple[tuple, float, np.ndarray, np.ndarray]:
    from repro.runtime import archive_io
    r = archive_io._Reader(payload, "zfp-like payload")
    if r.take(4) != _MAGIC:
        raise MalformedStream("zfp-like payload: bad magic")
    nd = r.u8()
    if not 1 <= nd <= _MAX_DIMS:
        raise MalformedStream(f"zfp-like payload: absurd rank {nd}")
    shape = struct.unpack(f"<{nd}I", r.take(4 * nd))
    tol, scale_len = struct.unpack("<dQ", r.take(16))
    if not tol > 0:
        raise MalformedStream(f"zfp-like payload: bad tolerance {tol}")
    grid = tuple((s + 3) // 4 for s in shape)
    nb = int(np.prod(grid))
    scale_raw = entropy.zlib_unpack(r.take(scale_len))
    if len(scale_raw) != nb:
        raise MalformedStream(
            f"zfp-like scale table holds {len(scale_raw)} exponents, "
            f"expected {nb}")
    log2_scale = np.frombuffer(scale_raw, np.int8)
    stream = archive_io._unpack_stream(r)
    q = (entropy.huffman_decompress(stream) if stream is not None
         else np.zeros(0, np.int64))
    want = nb * 4 ** nd
    if q.size != want:
        raise MalformedStream(
            f"zfp-like stream has {q.size} coefficients, expected {want}")
    return shape, tol, log2_scale, q.reshape(nb, 4 ** nd)


# -- legacy module-level surface --------------------------------------------

def compress(data: np.ndarray, tol: float) -> tuple[np.ndarray, int]:
    """Tolerance-targeted compression. Returns (decoded, compressed_bytes).

    ``compressed_bytes`` is the length of the REAL decodable payload
    (``ZFPLikeCodec``), not an estimate.
    """
    c = ZFPLikeCodec()
    enc = c.compress(data, tol)
    return c.decompress(enc), enc.nbytes


def compression_curve(data: np.ndarray, tols: list[float]) -> list[dict]:
    """CR / NRMSE points for a sweep of tolerances."""
    return codec_mod.compression_curve(ZFPLikeCodec(), data, tols,
                                       bound_key="tol")
