"""Config registry: ``get_config("<arch-id>")`` for the 10 assigned
architectures plus the paper's own compressor app configs (s3d/e3sm/xgc)."""
from __future__ import annotations

import importlib

from repro.configs.base import (LM_SHAPES, ModelConfig, RunConfig, ShapeConfig,
                                shape_applicable)

_ARCH_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-medium": "whisper_medium",
    "mamba2-370m": "mamba2_370m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def get_compressor_config(dataset: str):
    mod = importlib.import_module(f"repro.configs.{dataset}")
    return mod.CONFIG
