"""Config dataclasses: model architecture, run/parallelism, input shapes.

The per-arch files in this package hold the EXACT assigned configurations;
physical padding for tensor parallelism (vocab to a multiple of 256*TP,
Q-heads to a multiple of TP, KV-head replication up to TP) is derived here and
is an implementation artifact, not a config change — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq: int = 524_288
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- hybrid (RG-LRU / Griffin) ---
    attn_period: int = 0             # every `period`-th layer is local attention
    window: int = 0                  # sliding-window size for local attention
    lru_width: int = 0
    conv_width: int = 4
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500             # stub frontend: precomputed frame embeddings
    # --- VLM (llama-3.2-vision) ---
    cross_period: int = 0            # every `period`-th layer is cross-attention
    n_vision_tokens: int = 1601      # stub frontend: precomputed patch embeddings
    # --- shape-cell notes ---
    subquadratic: bool = False       # may run long_500k
    has_decoder: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def padded_vocab(self, tp: int) -> int:
        if self.vocab % tp == 0 and tp == 1:
            return self.vocab
        return pad_to(self.vocab, 256 * tp if self.vocab % tp else tp)

    def padded_heads(self, tp: int) -> tuple[int, int]:
        """(physical q heads, physical kv heads) under tensor parallelism."""
        hq = pad_to(self.n_heads, tp)
        hkv = self.n_kv_heads if self.n_kv_heads % tp == 0 else pad_to(self.n_kv_heads, tp)
        hkv = min(hkv, hq)
        return hq, hkv


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution-time knobs: parallelism, dtypes, remat."""
    tp: int = 1                      # size of the "model" mesh axis
    dp: int = 1                      # size of the "data" (x pod) axes
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    use_flash_kernel: bool = False   # Pallas path (TPU); jnp reference on CPU
    scan_layers: bool = True         # False: unroll stacks (cost-faithful HLO
    #                                  for dry-run analysis; DESIGN.md §8)
    ce_chunk: int = 0                # >0: sequence-chunked fused LM-head+CE —
    #                                  the (B,S,V) logits tensor never fully
    #                                  materializes (§Perf hillclimb lever)
    sp: bool = False                 # sequence-parallel activation sharding
    #                                  (reduce-scatter/all-gather TP boundary)
    moe_dispatch_groups: int = 0     # >1: per-group (shard-local) MoE dispatch
    #                                  instead of one global token sort
    cast_params_early: bool = False  # cast fp32 masters to compute dtype at
    #                                  the top of the loss: FSDP all-gathers
    #                                  and grad reductions run in bf16 (§Perf)
    gradient_compression: str = "none"   # none | pca_ef | gae
    grad_comp_rank: int = 32
    grad_comp_tau: float = 0.0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch x shape) cell runs; reason string when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch — O(n^2) attention and a "
                       ">HBM KV cache at 524288 tokens (DESIGN.md §5)")
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "skipped: encoder-only arch has no decode step"
    return True, ""
