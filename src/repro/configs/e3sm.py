"""Paper E3SM setup (Sec. III): blocks (6,16,16) -> 1536; k=5 per hyper-block;
GAE at (16,16)=256; latent 64; bins 0.01 (HBAE) / 0.1 (BAE)."""
from repro.core.pipeline import CompressorConfig

CONFIG = CompressorConfig(
    block_elems=6 * 16 * 16, k=5, emb=128, hidden=512, hb_latent=64,
    bae_hidden=512, bae_latent=16, hb_bin=0.01, bae_bin=0.1, gae_bin=0.02,
    gae_block_elems=16 * 16)

BLOCK_SHAPE = (6, 16, 16)          # (t, y, x)
HYPERBLOCK_K = 5
NORMALIZATION = "zscore"
