"""granite-moe-3b-a800m — MoE 40 experts top-8 (per-expert d_ff=512).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64, rope_theta=10000.0, tie_embeddings=True,
    n_experts=40, top_k=8, shared_expert=False)
