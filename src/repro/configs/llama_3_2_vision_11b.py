"""llama-3.2-vision-11b — decoder + cross-attention every 5th layer; the
vision frontend is a stub (precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=500000.0,
    cross_period=5, n_vision_tokens=1601)
