"""mamba2-370m — SSD (state-space duality), attention-free, d_ff=0.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    ssm_state=128, expand=2, ssm_headdim=64, ssm_chunk=256, ssm_ngroups=1,
    subquadratic=True)
