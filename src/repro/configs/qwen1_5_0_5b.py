"""qwen1.5-0.5b — dense, QKV bias, tied embeddings. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, head_dim=64, rope_theta=1000000.0,
    qkv_bias=True, tie_embeddings=True)
