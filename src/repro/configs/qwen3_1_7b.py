"""qwen3-1.7b — dense, qk-norm, GQA. [hf:Qwen/Qwen3-8B (family); hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, head_dim=128, rope_theta=1000000.0,
    qk_norm=True, tie_embeddings=True)
