"""recurrentgemma-9b — RG-LRU + local attention, (rec,rec,attn) 2:1 pattern.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, rope_theta=10000.0,
    attn_period=3, window=2048, lru_width=4096, conv_width=4,
    subquadratic=True)
