"""Paper S3D setup (Sec. III): blocks (58,5,4,4) -> flattened 4640; k=10
temporal blocks per hyper-block; GAE per species at (5,4,4)=80; latent 128;
bins 0.005/0.005."""
from repro.core.pipeline import CompressorConfig

CONFIG = CompressorConfig(
    block_elems=58 * 5 * 4 * 4, k=10, emb=128, hidden=512, hb_latent=128,
    bae_hidden=512, bae_latent=16, hb_bin=0.005, bae_bin=0.005, gae_bin=0.01,
    gae_block_elems=5 * 4 * 4)

BLOCK_SHAPE = (58, 5, 4, 4)        # (species, t, y, x)
HYPERBLOCK_K = 10
NORMALIZATION = "range"            # per-species mean 0 / range 1
