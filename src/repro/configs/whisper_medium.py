"""whisper-medium — encoder-decoder (24+24L); conv/mel frontend is a stub
(precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64,
    n_enc_layers=24, n_frames=1500)
