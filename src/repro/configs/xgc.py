"""Paper XGC setup (Sec. III): each (39,39) histogram is a block; the 8
toroidal planes at one node form a hyper-block; GAE per histogram (1521);
latent 64; bins 0.1/0.1."""
from repro.core.pipeline import CompressorConfig

CONFIG = CompressorConfig(
    block_elems=39 * 39, k=8, emb=128, hidden=512, hb_latent=64,
    bae_hidden=512, bae_latent=16, hb_bin=0.1, bae_bin=0.1, gae_bin=0.05,
    gae_block_elems=39 * 39)

BLOCK_SHAPE = (39, 39)             # one velocity histogram
HYPERBLOCK_K = 8
NORMALIZATION = "zscore"
