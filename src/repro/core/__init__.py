"""The paper's contribution: attention-based hierarchical compression with
guaranteed error bounds (HBAE + BAE + GAE + bitstream)."""
from repro.core.pipeline import Archive, CompressorConfig, HierarchicalCompressor  # noqa: F401
