"""The paper's contribution: attention-based hierarchical compression with
guaranteed error bounds (HBAE + BAE + GAE + bitstream)."""
from repro.core.errors import (ArchiveError, ChecksumMismatch, ChunkDamage,  # noqa: F401
                               DamageReport, GuaranteeUnsatisfiable,
                               MalformedStream, TruncatedArchive)
from repro.core.pipeline import (Archive, ArchiveChunk, CompressorConfig,  # noqa: F401
                                 HierarchicalCompressor)
