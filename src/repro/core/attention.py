"""Self-attention primitives for the hyper-block autoencoder (paper Eqs. 2-6).

The paper uses a single plain self-attention layer over the ``k`` block
embeddings of one hyper-block (sequence length = k, embedding dim = d), wrapped
as ``e~ = Atten(norm(e)) + e`` (Eq. 6).  We implement it multi-head-capable
(heads=1 reproduces the paper exactly) and route the core computation through
the fused Pallas kernel when requested (``repro.kernels.block_attention``).

Everything here is expressed over batched hyper-blocks: inputs are
``(B, k, d)`` where B is the number of hyper-blocks in the batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class AttnMeta:
    """Static (non-traced) attention hyperparameters carried in the params tree."""
    heads: int


# ---------------------------------------------------------------------------
# layer norm
# ---------------------------------------------------------------------------

def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

def linear_init(key: Array, d_in: int, d_out: int, bias: bool = True) -> dict:
    wkey, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(d_in)
    p = {"w": jax.random.uniform(wkey, (d_in, d_out), jnp.float32, -scale, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(params: dict, x: Array) -> Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ---------------------------------------------------------------------------
# self-attention (paper Eq. 2-3)
# ---------------------------------------------------------------------------

def attention_init(key: Array, d: int, d_k: Optional[int] = None,
                   d_v: Optional[int] = None, heads: int = 1) -> dict:
    """Learned W_Q, W_K, W_V (+ output proj when d_v != d or heads > 1)."""
    d_k = d_k or d
    d_v = d_v or d
    assert d_k % heads == 0 and d_v % heads == 0
    kq, kk, kv, ko = jax.random.split(key, 4)
    params = {
        "wq": linear_init(kq, d, d_k, bias=False),
        "wk": linear_init(kk, d, d_k, bias=False),
        "wv": linear_init(kv, d, d_v, bias=False),
        "wo": linear_init(ko, d_v, d, bias=False),
        "meta": AttnMeta(heads=heads),
    }
    return params


def self_attention(params: dict, x: Array, *, use_kernel: bool = False) -> Array:
    """Plain softmax self-attention over axis -2.  x: (..., k, d) -> (..., k, d)."""
    heads = params["meta"].heads
    q = linear(params["wq"], x)
    k = linear(params["wk"], x)
    v = linear(params["wv"], x)
    if use_kernel:
        from repro.kernels.block_attention import ops as ba_ops
        ctx = ba_ops.block_attention(q, k, v, heads=heads)
    else:
        ctx = _reference_attention(q, k, v, heads)
    return linear(params["wo"], ctx)


def _reference_attention(q: Array, k: Array, v: Array, heads: int) -> Array:
    *lead, n, dk = q.shape
    dv = v.shape[-1]
    hq = q.reshape(*lead, n, heads, dk // heads)
    hk = k.reshape(*lead, n, heads, dk // heads)
    hv = v.reshape(*lead, n, heads, dv // heads)
    scores = jnp.einsum("...qhd,...khd->...hqk", hq, hk) / jnp.sqrt(dk // heads)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    ctx = jnp.einsum("...hqk,...khd->...qhd", w, hv)
    return ctx.reshape(*lead, n, dv)


def attention_block_init(key: Array, d: int, heads: int = 1) -> dict:
    """The full Eq.6 block: e~ = Atten(norm(e)) + e."""
    return {"ln": layernorm_init(d), "attn": attention_init(key, d, heads=heads)}


def attention_block(params: dict, e: Array, *, use_kernel: bool = False) -> Array:
    return self_attention(params["attn"], layernorm(params["ln"], e),
                          use_kernel=use_kernel) + e
