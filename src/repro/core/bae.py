"""Block-wise residual AutoEncoder (BAE) — paper Sec. II-C (Eqs. 7-8).

Operates on per-block residuals r_i = x_i - y_i from the HBAE.  Residual values
are small, so the paper applies layer normalization to rescale them before the
encoder; the decoder learns to emit the *unnormalized* residual, which is added
back onto y_i:

    L_b  = E(norm(x_i - y_i))          (Eq. 7)
    x^R  = D(L_b) + y_i                (Eq. 8)

Shapes: residuals are (B, in_dim) flattened blocks; latent (B, latent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attention import layernorm, layernorm_init
from repro.core.hbae import mlp2, mlp2_init

Array = jax.Array


def bae_init(key: Array, *, in_dim: int, hidden: int = 256, latent: int = 16) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln": layernorm_init(in_dim),
        "enc": mlp2_init(k1, in_dim, hidden, latent),
        "dec": mlp2_init(k2, latent, hidden, in_dim),
    }


def bae_encode(params: dict, residual: Array) -> Array:
    return mlp2(params["enc"], layernorm(params["ln"], residual))


def bae_decode(params: dict, latent: Array) -> Array:
    return mlp2(params["dec"], latent)


def bae_apply(params: dict, residual: Array) -> tuple[Array, Array]:
    """Returns (reconstructed residual r_hat, latent L_b)."""
    latent = bae_encode(params, residual)
    return bae_decode(params, latent), latent
