"""Entropy coding (paper Sec. II-E): Huffman for quantized coefficients,
prefix-bitmask + lossless backend for PCA index sets.

The paper uses ZSTD for the concatenated index bitmasks; ``zstandard`` is not
available offline, so we use stdlib zlib (DEFLATE) behind the same interface —
mechanism identical, ratios differ by a few percent (noted in DESIGN.md §4).

All of this is host-side (numpy + bytes): on a real deployment the TPU emits
quantized integer tensors and the host feeders run this lossless pass, exactly
mirroring the paper's factorization (quantization in-graph, Huffman post-hoc).
"""
from __future__ import annotations

import heapq
import struct
import zlib
from typing import NamedTuple, Optional

import numpy as np

from repro.core.errors import MalformedStream, TruncatedArchive

MAX_CODE_LEN = 16

# DEFLATE effort for the index/bin-exp blobs.  Level 9 spent ~40% of chunk
# encode time for <1% ratio over level 6 on the bitmask payloads (measured in
# BENCH_pipeline.json); 6 is the hot-path sweet spot.
_ZLIB_LEVEL = 6


# ---------------------------------------------------------------------------
# canonical Huffman
# ---------------------------------------------------------------------------

class HuffmanBook(NamedTuple):
    symbols: np.ndarray   # (S,) int64, sorted by (length, symbol)
    lengths: np.ndarray   # (S,) uint8
    codes: np.ndarray     # (S,) uint32 canonical codes

    def nbytes(self) -> int:
        """Serialized codebook cost: symbol values + code lengths."""
        return self.symbols.size * 8 + self.lengths.size


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths via heap; freqs > 0."""
    n = freqs.size
    if n == 1:
        return np.array([1], np.uint8)
    heap: list[tuple[float, int, object]] = [(float(f), i, i) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    lengths = np.zeros(n, np.int64)
    counter = n
    while len(heap) > 1:
        fa, _, a = heapq.heappop(heap)
        fb, _, b = heapq.heappop(heap)
        heapq.heappush(heap, (fa + fb, counter, (a, b)))
        counter += 1
    stack = [(heap[0][2], 0)]
    while stack:
        node, depth = stack.pop()
        if isinstance(node, tuple):
            stack.append((node[0], depth + 1))
            stack.append((node[1], depth + 1))
        else:
            lengths[node] = max(depth, 1)
    return lengths


def build_huffman(values: np.ndarray) -> HuffmanBook:
    """Canonical Huffman book over observed symbols, code length capped at 16."""
    syms, freqs = np.unique(np.asarray(values).ravel(), return_counts=True)
    f = freqs.astype(np.float64)
    lengths = _code_lengths(f)
    while lengths.max() > MAX_CODE_LEN:
        f = np.ceil(np.power(f, 0.9))            # flatten distribution, retry
        lengths = _code_lengths(f)
    # canonical ordering: (length, symbol)
    order = np.lexsort((syms, lengths))
    syms, lengths = syms[order], lengths[order]
    codes = np.zeros(syms.size, np.uint32)
    code = 0
    prev_len = int(lengths[0])
    for i in range(syms.size):
        code <<= int(lengths[i]) - prev_len
        codes[i] = code
        prev_len = int(lengths[i])
        code += 1
    return HuffmanBook(symbols=syms.astype(np.int64),
                       lengths=lengths.astype(np.uint8), codes=codes)


def huffman_encode(values: np.ndarray, book: HuffmanBook) -> bytes:
    """Vectorized bit-packing of values through the codebook."""
    v = np.asarray(values).ravel().astype(np.int64)
    # book is in canonical (length, symbol) order — not value-sorted; map
    # through a value-sorted view for the searchsorted lookup.
    order = np.argsort(book.symbols, kind="stable")
    sorted_syms = book.symbols[order]
    idx = order[np.searchsorted(sorted_syms, v)]
    assert np.all(book.symbols[idx] == v), "symbol not in codebook"
    lens = book.lengths[idx].astype(np.int64)
    codes = book.codes[idx].astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return b""
    pos = np.concatenate([[0], np.cumsum(lens)[:-1]])
    block = np.repeat(np.arange(v.size), lens)
    within = np.arange(total) - np.repeat(pos, lens)
    bits = (codes[block] >> (lens[block] - 1 - within)) & 1
    return np.packbits(bits.astype(np.uint8)).tobytes()


def rebuild_canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Reconstruct canonical codes from (length,symbol)-sorted code lengths.

    This is the untrusted inverse of ``build_huffman``'s assignment loop: the
    on-disk book stores only symbols + lengths, and this validates that the
    lengths describe a realizable prefix code (in-range, sorted, Kraft-
    feasible) before any decode table is built from them.
    """
    lengths = np.asarray(lengths)
    if lengths.size == 0:
        return np.zeros(0, np.uint32)
    if lengths.min() < 1 or lengths.max() > MAX_CODE_LEN:
        raise MalformedStream(
            f"Huffman code length out of range [1, {MAX_CODE_LEN}]")
    if np.any(np.diff(lengths.astype(np.int64)) < 0):
        raise MalformedStream("Huffman code lengths not in canonical order")
    codes = np.zeros(lengths.size, np.uint32)
    code = 0
    prev_len = int(lengths[0])
    for i in range(lengths.size):
        li = int(lengths[i])
        code <<= li - prev_len
        if code >= (1 << li):
            raise MalformedStream("Huffman code space overflow (Kraft violation)")
        codes[i] = code
        prev_len = li
        code += 1
    return codes


def rebuild_book(symbols: np.ndarray, lengths: np.ndarray) -> HuffmanBook:
    """Validated ``HuffmanBook`` from untrusted serialized (symbols, lengths)."""
    symbols = np.asarray(symbols, np.int64)
    lengths = np.asarray(lengths, np.uint8)
    if symbols.size != lengths.size:
        raise MalformedStream("Huffman book symbol/length count mismatch")
    return HuffmanBook(symbols=symbols, lengths=lengths,
                       codes=rebuild_canonical_codes(lengths))


# Below this symbol count the fully-vectorized decode's setup cost exceeds
# the scalar loop; measured crossover is a few hundred symbols.
_VECTOR_DECODE_MIN = 256


def _decode_table(book: HuffmanBook) -> tuple[np.ndarray, np.ndarray]:
    """(table_sym, table_len) 2^16 lookup tables; table_len 0 = invalid."""
    table_sym = np.zeros(1 << MAX_CODE_LEN, np.int64)
    table_len = np.zeros(1 << MAX_CODE_LEN, np.uint8)
    for s, l, c in zip(book.symbols, book.lengths, book.codes):
        l = int(l)
        if not 1 <= l <= MAX_CODE_LEN:
            raise MalformedStream(f"Huffman code length {l} out of range")
        base = int(c) << (MAX_CODE_LEN - l)
        span = 1 << (MAX_CODE_LEN - l)
        if base + span > (1 << MAX_CODE_LEN):
            raise MalformedStream("Huffman code outside table range")
        table_sym[base:base + span] = s
        table_len[base:base + span] = l
    return table_sym, table_len


def _decode_prologue(data: bytes, book: HuffmanBook, count: int):
    if count < 0:
        raise MalformedStream(f"negative symbol count {count}")
    if book.symbols.size == 0:
        raise MalformedStream("empty Huffman book with nonzero symbol count")
    table_sym, table_len = _decode_table(book)
    bits = np.unpackbits(np.frombuffer(data, np.uint8))
    bits = np.concatenate([bits, np.zeros(MAX_CODE_LEN, np.uint8)])  # tail pad
    return table_sym, table_len, bits, len(data) * 8


def huffman_decode_scalar(data: bytes, book: HuffmanBook, count: int) -> np.ndarray:
    """Reference table-driven decode: one Python iteration per symbol.  Kept
    as the oracle for the vectorized path (and for small streams, where it is
    faster); identical output and error behavior."""
    if count == 0:
        return np.zeros(0, np.int64)
    table_sym, table_len, bits, total_bits = _decode_prologue(data, book, count)
    out = np.empty(count, np.int64)
    pos = 0
    weights = (1 << np.arange(MAX_CODE_LEN - 1, -1, -1)).astype(np.int64)
    for i in range(count):
        w = int(bits[pos:pos + MAX_CODE_LEN] @ weights)
        step = int(table_len[w])
        if step == 0:
            raise MalformedStream(f"undecodable Huffman prefix at bit {pos}")
        if pos + step > total_bits:
            raise TruncatedArchive(
                f"Huffman payload exhausted at symbol {i}/{count}")
        out[i] = table_sym[w]
        pos += step
    return out


def huffman_decode(data: bytes, book: HuffmanBook, count: int) -> np.ndarray:
    """Table-driven decode (2^16 lookup), bounds-checked against corrupt input:
    an undecodable prefix raises ``MalformedStream`` and running out of payload
    bits before ``count`` symbols raises ``TruncatedArchive``.

    Large streams take a vectorized path: every bit position's (symbol, step)
    is computed in one numpy pass, then the decode chain pos -> pos + step is
    enumerated by pointer doubling — O(total_bits * log(count)) numpy work
    with no per-symbol Python iteration, and GIL-releasing so independent
    chunks decode in parallel (see ``core.exec.map_parallel``).  Output and
    typed-error behavior are identical to ``huffman_decode_scalar`` (the
    chain is deterministic up to the first damaged position, which is
    reported exactly as the scalar loop would).
    """
    if count == 0:
        return np.zeros(0, np.int64)
    if count < _VECTOR_DECODE_MIN:
        return huffman_decode_scalar(data, book, count)
    if book.symbols.size == 0:
        raise MalformedStream("empty Huffman book with nonzero symbol count")
    table_sym, table_len = _decode_table(book)
    total_bits = len(data) * 8

    # The 16-bit window at EVERY bit position 0..total_bits, read straight
    # out of zero-padded byte triples: window(p) spans bytes p>>3 .. p>>3+2,
    # so one gather + two shifts beats both unpackbits and a 16-pass build.
    buf = np.frombuffer(data, np.uint8).astype(np.uint32)
    ext = np.concatenate([buf, np.zeros(3, np.uint32)])
    b3 = (ext[:-2] << 16) | (ext[1:-1] << 8) | ext[2:]
    n_pos = total_bits + 1
    pos_all = np.arange(n_pos, dtype=np.int64)
    windows = ((b3[pos_all >> 3] << (pos_all & 7)) >> 8) & 0xFFFF
    step = table_len[windows]                          # uint8; 0 = invalid

    # Successor of each position; invalid prefixes (step 0) self-loop and
    # overruns clamp in-range so the doubling below stays well-defined — the
    # post-scan reports the first error in chain order.
    idx = np.arange(n_pos, dtype=np.int32)
    nxt = np.minimum(np.where(step == 0, idx, idx + step),
                     np.int32(n_pos - 1))

    # Pointer doubling: after k rounds ``pos`` holds the bit positions of the
    # first 2^k symbols in order and ``jump`` advances 2^k symbols at once.
    pos = np.zeros(1, np.int32)
    jump = nxt
    while pos.size < count:
        pos = np.concatenate([pos, jump[pos]])
        if pos.size < count:
            jump = jump[jump]
    pos = pos[:count]

    step_v = step[pos]
    bad = step_v == 0
    trunc = pos.astype(np.int64) + step_v > total_bits
    if bad.any() or trunc.any():
        first = int(np.argmax(bad | trunc))
        if bad[first]:
            raise MalformedStream(
                f"undecodable Huffman prefix at bit {int(pos[first])}")
        raise TruncatedArchive(
            f"Huffman payload exhausted at symbol {first}/{count}")
    return table_sym[windows[pos]]


class HuffmanStream(NamedTuple):
    payload: bytes
    book: HuffmanBook
    count: int

    def nbytes(self) -> int:
        return len(self.payload) + self.book.nbytes() + 8


def huffman_compress(values: np.ndarray) -> HuffmanStream:
    book = build_huffman(values)
    return HuffmanStream(huffman_encode(values, book), book, int(np.asarray(values).size))


def huffman_decompress(stream: HuffmanStream) -> np.ndarray:
    return huffman_decode(stream.payload, stream.book, stream.count)


def huffman_size_bits(values: np.ndarray) -> int:
    """Exact coded size in bits without materializing the stream (for ratio math)."""
    book = build_huffman(values)
    v = np.asarray(values).ravel().astype(np.int64)
    order = np.argsort(book.symbols, kind="stable")
    idx = order[np.searchsorted(book.symbols[order], v)]
    return int(book.lengths[idx].astype(np.int64).sum()) + book.nbytes() * 8


# ---------------------------------------------------------------------------
# index bitmask coding (paper Fig. 3)
# ---------------------------------------------------------------------------

def encode_index_sets(index_sets: list[np.ndarray], dim: int) -> bytes:
    """'1' marks a selected basis vector; store only the shortest prefix that
    contains all 1s, plus its length; concatenate and DEFLATE.

    Whole-batch implementation (one scatter into an (n, dim) mask matrix, one
    boolean prefix-select) — the per-set Python loop this replaces dominated
    chunk encode time at production block counts.
    """
    n = len(index_sets)
    sizes = np.fromiter((np.asarray(s).size for s in index_sets), np.int64, n)
    total = int(sizes.sum())
    plen = np.zeros(n, np.int64)
    if total:
        rows = np.repeat(np.arange(n), sizes)
        cols = np.concatenate([np.asarray(s, np.int64).ravel()
                               for s in index_sets])
        masks = np.zeros((n, dim), np.uint8)
        masks[rows, cols] = 1
        # per-set max index + 1; consecutive nonempty starts bound exactly
        # the nonempty segments (empty segments collapse to zero width)
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        nz = sizes > 0
        plen[nz] = np.maximum.reduceat(cols, starts[nz]) + 1
        bits = masks[np.arange(dim)[None, :] < plen[:, None]]
    else:
        bits = np.zeros(0, np.uint8)
    header = struct.pack("<II", n, dim)
    lens_b = plen.astype(np.uint32).tobytes()
    payload = np.packbits(bits).tobytes() if bits.size else b""
    return zlib.compress(header + lens_b + payload, level=_ZLIB_LEVEL)


def decode_index_sets(blob: bytes, expect_dim: Optional[int] = None,
                      expect_sets: Optional[int] = None) -> list[np.ndarray]:
    """Decode (and validate) the index bitmask blob.

    ``expect_dim`` / ``expect_sets`` cross-check the self-declared header
    against what the caller knows (basis dimension, GAE block count) so a
    corrupt-but-decompressible blob cannot smuggle out-of-range indices into
    the basis gather downstream.
    """
    try:
        raw = zlib.decompress(blob)
    except zlib.error as e:
        raise MalformedStream(f"index blob DEFLATE error: {e}") from e
    if len(raw) < 8:
        raise TruncatedArchive("index blob shorter than its header")
    n, dim = struct.unpack("<II", raw[:8])
    if expect_dim is not None and dim != expect_dim:
        raise MalformedStream(
            f"index blob dimension {dim} != basis dimension {expect_dim}")
    if expect_sets is not None and n != expect_sets:
        raise MalformedStream(f"index blob has {n} sets, expected {expect_sets}")
    if len(raw) < 8 + 4 * n:
        raise TruncatedArchive("index blob length table truncated")
    lens = np.frombuffer(raw[8:8 + 4 * n], np.uint32).astype(np.int64)
    if lens.size and lens.max() > dim:
        raise MalformedStream(
            f"index prefix length {int(lens.max())} exceeds dimension {dim}")
    bits = np.unpackbits(np.frombuffer(raw[8 + 4 * n:], np.uint8))
    if int(lens.sum()) > bits.size:
        raise TruncatedArchive("index bitmask payload truncated")
    # one flatnonzero over the concatenated prefixes, then per-set views via
    # searchsorted cuts — no per-set Python nonzero on the hot decode path
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=offs[1:])
    nzpos = np.flatnonzero(bits[:offs[-1]])
    seg = np.searchsorted(offs, nzpos, side="right") - 1
    local = (nzpos - offs[seg]).astype(np.int32)
    cuts = np.searchsorted(nzpos, offs)
    return [local[cuts[i]:cuts[i + 1]] for i in range(n)]


def zlib_pack(data: bytes) -> bytes:
    return zlib.compress(data, level=_ZLIB_LEVEL)


def zlib_unpack(data: bytes) -> bytes:
    try:
        return zlib.decompress(data)
    except zlib.error as e:
        raise MalformedStream(f"DEFLATE error: {e}") from e
