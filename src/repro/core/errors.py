"""Typed failure taxonomy + damage reporting for the archive read path.

Every failure on the untrusted decode path (on-disk container, Huffman
bitstreams, index bitmasks, model manifests) is raised as a subclass of
``ArchiveError`` — never a raw ``struct.error`` / ``zlib.error`` /
``IndexError``.  Callers can therefore distinguish "this archive is damaged"
from programming errors, and ``decompress(strict=False)`` can degrade
gracefully per chunk instead of crashing.
"""
from __future__ import annotations

import dataclasses


class ArchiveError(Exception):
    """Base class for all archive/bitstream decode failures."""


class TruncatedArchive(ArchiveError):
    """The container or a stream ended before its declared length."""


class ChecksumMismatch(ArchiveError):
    """A section's CRC32/sha256 digest does not match its contents."""


class MalformedStream(ArchiveError):
    """A stream is structurally invalid (bad magic, impossible code lengths,
    out-of-range indices, count mismatches, undecodable prefix, ...)."""


class ConfigError(ValueError):
    """A compression run was configured with values that can never execute
    (zero-width chunks, an empty device mesh, a mesh without the hyper-block
    data axis, more shards than devices, ...).

    Raised at ``CompressOptions`` CONSTRUCTION / mesh-resolution time — before
    any model program is built — so a bad ``--mesh``/``--chunk-hyperblocks``
    combination surfaces as one typed error instead of a mid-run XLA shape
    crash deep inside a sharded trace.
    """


class TransientStageError(Exception):
    """A pipeline-stage failure presumed recoverable by retrying the SAME
    item on the SAME stage (worker-pool hiccup, transient ``OSError`` from
    the sink, injected chaos).  The streaming scheduler's ``RetryPolicy``
    retries these with seeded exponential backoff; anything else is a
    permanent failure and goes straight to failover/quarantine.

    Wrap the underlying cause with ``raise TransientStageError(...) from e``
    so diagnostics keep the original traceback.
    """


class StageDeadlineExceeded(TransientStageError):
    """A stage worker blew past its per-item deadline (hung device call,
    stuck host coder).  The watchdog abandons the attempt — the hung call
    keeps running on a discarded thread, its result is ignored — and the
    scheduler treats the item as transiently failed: retry, then quarantine.
    Subclasses ``TransientStageError`` because hangs are usually stragglers,
    not poison.
    """

    def __init__(self, stage: str, item: int, deadline_s: float):
        self.stage = str(stage)
        self.item = int(item)
        self.deadline_s = float(deadline_s)
        super().__init__(
            f"stage {stage!r} item {item}: no result within the "
            f"{deadline_s:g}s deadline — attempt abandoned by the watchdog")


class GuaranteeUnsatisfiable(Exception):
    """The GAE encoder could not bring a block's l2 error under ``tau``.

    Raised on the ENCODE side (not an ``ArchiveError``): it means the
    verify-and-repair loop exhausted its refinement budget with ``err > tau``
    — e.g. a rank-deficient basis that cannot span the residual, or a
    ``max_refine`` cap too small for the requested bound.  Before this error
    existed the encoder silently emitted a guarantee-violating block.
    """

    def __init__(self, block: int, err: float, tau: float, max_refine: int):
        self.block = int(block)
        self.err = float(err)
        self.tau = float(tau)
        self.max_refine = int(max_refine)
        super().__init__(
            f"GAE block {block}: residual l2 {err:.6g} > tau {tau:.6g} after "
            f"exhausting max_refine={max_refine} bin refinements — the "
            f"guarantee cannot be honored for this block")


@dataclasses.dataclass
class ChunkDamage:
    """One damaged hyper-block stripe of an archive."""
    chunk: int              # chunk index in the container
    hb_start: int           # first hyper-block covered by the chunk
    n_hyperblocks: int      # hyper-blocks covered by the chunk
    section: str            # which part failed ("chunk", "hb_stream", "gae", ...)
    error: str              # repr of the underlying ArchiveError


@dataclasses.dataclass
class DamageReport:
    """Per-chunk damage accounting from a tolerant (``strict=False``) decode.

    Hyper-blocks listed here carry NO guarantee; every hyper-block not listed
    decoded from digest-verified, cross-checked streams and still satisfies the
    per-block l2 <= tau bound.
    """
    n_hyperblocks: int
    n_chunks: int
    damaged: list[ChunkDamage] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.damaged

    def damaged_hyperblocks(self) -> set[int]:
        out: set[int] = set()
        for d in self.damaged:
            out.update(range(d.hb_start, d.hb_start + d.n_hyperblocks))
        return out

    def intact_fraction(self) -> float:
        if self.n_hyperblocks == 0:
            return 1.0
        return 1.0 - len(self.damaged_hyperblocks()) / self.n_hyperblocks

    def summary(self) -> str:
        if self.ok:
            return f"intact: {self.n_chunks} chunks, {self.n_hyperblocks} hyper-blocks"
        lines = [f"damaged: {len(self.damaged_hyperblocks())}/"
                 f"{self.n_hyperblocks} hyper-blocks in "
                 f"{len({d.chunk for d in self.damaged})}/{self.n_chunks} chunks"]
        for d in self.damaged:
            lines.append(f"  chunk {d.chunk} [hb {d.hb_start}:"
                         f"{d.hb_start + d.n_hyperblocks}] {d.section}: {d.error}")
        return "\n".join(lines)
