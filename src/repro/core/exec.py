"""Persistent execution layer for the compression hot path.

Before this layer existed every call site in ``core.pipeline`` did
``jax.jit(fn)(args)`` inline: a *fresh* jit wrapper per call, which discards
jax's compilation cache and retraces + recompiles the model on every
``compress``/``decompress``.  This module owns three things instead:

1. **A persistent jitted-function cache** (``cache()``): one long-lived
   ``jax.jit`` wrapper per (name, static-args) key.  Under each wrapper jax's
   own trace cache keys on (params pytree structure, shape, dtype), so a
   repeated call with same-shaped inputs never retraces.  Every *actual*
   trace is counted (``retrace_counts()``) by a Python side effect that only
   runs at trace time — the regression gate in ``scripts/smoke.sh`` asserts
   the count stays 0 across repeated calls after warmup.

2. **Fused device-resident stage programs**: ``encode_frontend`` fuses
   HBAE-encode -> quantize -> dequantize -> HBAE-decode -> per-stage
   BAE-encode/quantize/decode/residual-update into ONE program, and
   ``decode_backend`` fuses dequantize -> HBAE/BAE decode -> residual sum
   into one program.  ``run_compress_stage`` chains them with the quantized
   latents staying on device, so a full compress front-end is one
   host->device transfer and one device->host transfer instead of the ~8
   ``np.asarray``/``jnp.asarray`` bounces of the old path.  Compress and
   decompress both obtain the AE reconstruction from the *same*
   ``decode_backend`` program, so the reconstruction the GAE guarantee was
   verified against is exactly the one the decoder reproduces.

3. **A shared worker pool** (``map_parallel``) for the chunk-striped entropy
   coders: archive chunks are independently codable by design (see
   docs/ARCHIVE_FORMAT.md), and the Huffman/index-set work is numpy/zlib
   dominated (GIL-releasing), so a thread pool scales the host-side loops.

Stage-level timing/throughput counters (``stage`` / ``stage_stats``) wrap
each hot-path phase; ``launch/compress.py`` prints them and
``benchmarks/bench_pipeline_throughput.py`` records them into
``BENCH_pipeline.json``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bae as bae_mod
from repro.core import hbae as hbae_mod
from repro.core.errors import TransientStageError
from repro.core.quantization import dequantize, quantize

Array = jax.Array


# ---------------------------------------------------------------------------
# persistent jit cache with retrace accounting
# ---------------------------------------------------------------------------

def _mesh_key(mesh) -> tuple:
    """Hashable identity of a device mesh for cache keying: axis names, axis
    sizes, and the flat device ids.  Sharded and single-device programs get
    DISTINCT cache entries, so running both in one process never retraces
    either (``mesh=None`` keys exactly like the pre-mesh cache did)."""
    if mesh is None:
        return ()
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


class JitCache:
    """One persistent ``jax.jit`` wrapper per (name, statics, mesh) key.

    The wrapper body increments a per-name retrace counter — the body only
    executes while jax is *tracing*, so the counter counts actual retraces
    (shape/dtype/structure changes), not calls.

    ``mesh`` extends the key for ``shard_map``-wrapped programs: a sharded
    program is pinned to the mesh it was built over, so the same ``name``
    may coexist at several mesh shapes (plus the unsharded ``mesh=None``
    entry) without evicting or retracing one another.  ``fn`` is only
    consulted on the first call for a given key; callers that rebuild a
    ``shard_map`` wrapper per call still hit the persistent entry.
    """

    def __init__(self):
        self._fns: dict = {}
        self._retraces: dict[str, int] = {}
        self._lock = threading.Lock()

    def get(self, name: str, fn: Callable, *,
            static_argnums: Sequence[int] = (),
            static_argnames: Sequence[str] = (),
            mesh=None) -> Callable:
        key = (name, tuple(static_argnums), tuple(static_argnames),
               _mesh_key(mesh))
        with self._lock:
            cached = self._fns.get(key)
            if cached is None:
                def counted(*args, __fn=fn, __name=name, **kwargs):
                    self.count_retrace(__name)
                    return __fn(*args, **kwargs)
                cached = jax.jit(counted, static_argnums=static_argnums,
                                 static_argnames=static_argnames)
                self._fns[key] = cached
        return cached

    def count_retrace(self, name: str) -> None:
        with self._lock:
            self._retraces[name] = self._retraces.get(name, 0) + 1

    def retrace_counts(self) -> dict[str, int]:
        with self._lock:
            return dict(self._retraces)

    def total_retraces(self) -> int:
        with self._lock:
            return sum(self._retraces.values())


_CACHE = JitCache()


def cache() -> JitCache:
    return _CACHE


def retrace_counts() -> dict[str, int]:
    return _CACHE.retrace_counts()


def total_retraces() -> int:
    return _CACHE.total_retraces()


# ---------------------------------------------------------------------------
# stage timing / throughput counters
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StageStat:
    calls: int = 0
    seconds: float = 0.0
    values: int = 0

    def values_per_s(self) -> float:
        return self.values / self.seconds if self.seconds > 0 else 0.0


_STAGES: dict[str, StageStat] = {}
_STAGE_LOCK = threading.Lock()


@contextlib.contextmanager
def stage(name: str, n_values: int = 0):
    """Time one hot-path stage; accumulates wall time + processed values.

    Thread-safe: the codec worker pool and the streaming scheduler both enter
    stages concurrently, so every read-modify-write of the accumulator happens
    under ``_STAGE_LOCK`` (the ``StageStat`` instances themselves are only
    ever mutated while the lock is held; ``stage_stats`` hands out copies).
    """
    t0 = time.perf_counter()
    try:
        yield
    finally:
        record_stage(name, time.perf_counter() - t0, n_values)


def record_stage(name: str, seconds: float, n_values: int = 0,
                 calls: int = 1) -> None:
    """Accumulate a pre-measured duration into a stage counter (the streaming
    scheduler measures busy time inside worker threads and folds it in here).
    Thread-safe."""
    with _STAGE_LOCK:
        st = _STAGES.setdefault(name, StageStat())
        st.calls += int(calls)
        st.seconds += float(seconds)
        st.values += int(n_values)


def stage_stats() -> dict[str, StageStat]:
    with _STAGE_LOCK:
        return {k: dataclasses.replace(v) for k, v in _STAGES.items()}


def reset_stage_stats() -> None:
    """Clear stage timings AND the gauge/counter registry."""
    with _STAGE_LOCK:
        _STAGES.clear()
        _COUNTERS.clear()


# -- gauge/counter registry (queue depths, overlap seconds, ...) ------------
# Scalar counters that don't fit the calls/seconds/values shape of StageStat:
# the streaming scheduler records max queue depths and measured device/host
# overlap here.  Shares _STAGE_LOCK so a stats snapshot is one lock hop.

_COUNTERS: dict[str, float] = {}


def counter_add(name: str, delta: float = 1.0) -> None:
    with _STAGE_LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0.0) + float(delta)


def counter_max(name: str, value: float) -> None:
    """Record a high-water mark (e.g. observed queue depth)."""
    with _STAGE_LOCK:
        if value > _COUNTERS.get(name, float("-inf")):
            _COUNTERS[name] = float(value)


def counters() -> dict[str, float]:
    with _STAGE_LOCK:
        return dict(_COUNTERS)


def stats_summary() -> str:
    """Human-readable per-stage throughput + counter + retrace report."""
    lines = []
    for name, st in sorted(stage_stats().items()):
        lines.append(f"{name}: {st.calls} calls, {st.seconds:.3f}s, "
                     f"{st.values_per_s() / 1e6:.2f} Mvalues/s")
    for name, value in sorted(counters().items()):
        lines.append(f"{name}: {value:g}")
    traces = retrace_counts()
    if traces:
        total = sum(traces.values())
        parts = ", ".join(f"{k}={v}" for k, v in sorted(traces.items()))
        lines.append(f"traces: {total} ({parts})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# shared worker pool for chunk-parallel entropy coding
# ---------------------------------------------------------------------------

_POOL: Optional[ThreadPoolExecutor] = None
_POOL_LOCK = threading.Lock()


def codec_workers() -> int:
    """Worker count for chunk-parallel entropy coding (env-overridable;
    ``REPRO_CODEC_WORKERS=1`` forces the serial path)."""
    env = os.environ.get("REPRO_CODEC_WORKERS", "")
    if env.strip():
        try:
            return max(1, int(env))
        except ValueError:
            pass
    return max(1, min(32, os.cpu_count() or 1))


def _pool() -> ThreadPoolExecutor:
    global _POOL
    with _POOL_LOCK:
        if _POOL is None:
            _POOL = ThreadPoolExecutor(max_workers=codec_workers(),
                                       thread_name_prefix="repro-codec")
        return _POOL


def reset_pool() -> None:
    """Tear down the shared codec pool; the next submission lazily rebuilds
    it.  Used by tests/chaos to emulate losing the host worker pool."""
    global _POOL
    with _POOL_LOCK:
        if _POOL is not None:
            _POOL.shutdown(wait=False, cancel_futures=True)
        _POOL = None


def pool_submit(fn: Callable, *args, **kwargs) -> Future:
    """Submit one call onto the shared codec pool (the streaming scheduler's
    host-encode stage rides the same workers as ``map_parallel``).

    Resilient to a torn-down pool: a submission refused because the executor
    was shut down rebuilds the pool once and resubmits; a second refusal
    surfaces as ``TransientStageError`` so the streaming retry ladder (not
    the caller) owns the failure.
    """
    global _POOL
    try:
        return _pool().submit(fn, *args, **kwargs)
    except RuntimeError:
        with _POOL_LOCK:
            _POOL = None
        try:
            return _pool().submit(fn, *args, **kwargs)
        except RuntimeError as e:
            raise TransientStageError(
                f"codec pool rejected submission: {e}") from e


def map_parallel(fn: Callable, items: Iterable) -> list:
    """``[fn(x) for x in items]`` across the shared pool, order-preserving.

    Falls back to the serial loop for <=1 items or a 1-worker configuration
    so behavior stays bit-identical and easy to force in tests.

    Exception semantics are DETERMINISTIC BY ITEM INDEX, not completion
    order: if several items raise, the exception propagated is always the one
    from the lowest-index failing item — exactly what the serial loop would
    raise — regardless of worker scheduling.  Items after the first detected
    failure are cancelled if they have not started; items before it always
    ran to completion, so a failing streaming compress is reproducible in
    tests.
    """
    items = list(items)
    if len(items) <= 1 or codec_workers() <= 1:
        return [fn(x) for x in items]
    futures = [pool_submit(fn, x) for x in items]
    results: list = []
    first_err: Optional[BaseException] = None
    for f in futures:
        if first_err is None:
            try:
                results.append(f.result())
            except BaseException as e:   # noqa: BLE001 — re-raised below
                first_err = e
        else:
            f.cancel()
    if first_err is not None:
        raise first_err
    return results


# ---------------------------------------------------------------------------
# fused device-resident stage programs
# ---------------------------------------------------------------------------

def _encode_frontend(hbae_params: dict, bae_params: list, x: Array,
                     hb_bin: float, bae_bin: float):
    """x -> (q_lh, [q_lb per stage]); the full quantized-latent front-end as
    one device program.  Residual chaining requires the intermediate decoded
    reconstruction, so the decode work happens here too — but the
    reconstruction handed to callers always comes from ``decode_backend`` so
    encode/decode agree bit-exactly."""
    latent = hbae_mod.hbae_encode(hbae_params, x)
    q_lh = quantize(latent, hb_bin)
    recon = hbae_mod.hbae_decode(hbae_params, dequantize(q_lh, hb_bin))
    q_lbs = []
    if bae_params:
        n, k, d = x.shape
        resid = (x - recon).reshape(n * k, d)
        for p in bae_params:
            lb = bae_mod.bae_encode(p, resid)
            q_lb = quantize(lb, bae_bin)
            r_hat = bae_mod.bae_decode(p, dequantize(q_lb, bae_bin))
            recon = recon + r_hat.reshape(n, k, d)
            resid = resid - r_hat
            q_lbs.append(q_lb)
    return q_lh, q_lbs


def _decode_backend(hbae_params: dict, bae_params: list, q_lh: Array,
                    q_lbs: list, hb_bin: float, bae_bin: float) -> Array:
    """(q_lh, [q_lb]) -> reconstruction, as one device program."""
    recon = hbae_mod.hbae_decode(hbae_params, dequantize(q_lh, hb_bin))
    for p, q_lb in zip(bae_params, q_lbs):
        r_hat = bae_mod.bae_decode(p, dequantize(q_lb, bae_bin))
        recon = recon + r_hat.reshape(recon.shape)
    return recon


def _recon_frontend(hbae_params: dict, bae_params: list, x: Array) -> Array:
    """AE reconstruction WITHOUT latent quantization (ablation path)."""
    y, _ = hbae_mod.hbae_apply(hbae_params, x)
    recon = y
    if bae_params:
        n, k, d = x.shape
        resid = (x - y).reshape(n * k, d)
        for p in bae_params:
            r_hat, _ = bae_mod.bae_apply(p, resid)
            recon = recon + r_hat.reshape(n, k, d)
            resid = resid - r_hat
    return recon


def _as_q32(q: np.ndarray) -> np.ndarray:
    """Entropy-decoded latents arrive int64; the device programs trace on the
    int32 the quantizer emits — cast host-side so the trace cache hits."""
    q = np.asarray(q)
    return q.astype(np.int32) if q.dtype != np.int32 else q


def run_compress_stage_async(hbae_params: dict, bae_params: list,
                             hyperblocks: np.ndarray, hb_bin: float,
                             bae_bin: float):
    """Dispatch the fused compress front-end WITHOUT blocking on the result.

    Returns the on-device ``(q_lh, [q_lb per stage], recon)`` arrays.  jax
    dispatch is asynchronous, so the call returns as soon as the programs are
    enqueued — the streaming scheduler dispatches stripe *i+1* while stripe
    *i*'s results are still being computed/fetched.  Pass the handles to
    ``fetch_compress_stage`` to materialize numpy arrays.
    """
    enc = _CACHE.get("encode_frontend", _encode_frontend)
    dec = _CACHE.get("decode_backend", _decode_backend)
    x = jnp.asarray(hyperblocks)
    q_lh, q_lbs = enc(hbae_params, bae_params, x, hb_bin, bae_bin)
    recon = dec(hbae_params, bae_params, q_lh, q_lbs, hb_bin, bae_bin)
    return q_lh, q_lbs, recon


def fetch_compress_stage(handles) -> tuple[np.ndarray, list[np.ndarray],
                                           np.ndarray]:
    """Block until the dispatched front-end finishes and fetch numpy results
    (the per-stripe ``device_get`` half of the double-buffered transfer)."""
    q_lh, q_lbs, recon = jax.device_get(handles)
    return np.asarray(q_lh), [np.asarray(q) for q in q_lbs], np.asarray(recon)


def run_compress_stage(hbae_params: dict, bae_params: list,
                       hyperblocks: np.ndarray, hb_bin: float, bae_bin: float
                       ) -> tuple[np.ndarray, list[np.ndarray], np.ndarray]:
    """Full device-resident compress front-end: one upload, two fused
    programs (latents stay on device between them), one download.

    Returns numpy ``(q_lh, [q_lb per stage], recon)``; ``recon`` is computed
    by the same ``decode_backend`` program ``run_decompress_stage`` uses, so
    the GAE encoder corrects exactly what the decoder will reproduce.
    """
    return fetch_compress_stage(run_compress_stage_async(
        hbae_params, bae_params, hyperblocks, hb_bin, bae_bin))


def run_decompress_stage(hbae_params: dict, bae_params: list,
                         q_lh: np.ndarray, q_lbs: list, hb_bin: float,
                         bae_bin: float) -> np.ndarray:
    """Fused dequantize+decode back-end: one upload, one program, one
    download."""
    dec = _CACHE.get("decode_backend", _decode_backend)
    dq_lh = jnp.asarray(_as_q32(q_lh))
    dq_lbs = [jnp.asarray(_as_q32(q)) for q in q_lbs]
    recon = np.asarray(jax.device_get(
        dec(hbae_params, bae_params, dq_lh, dq_lbs, hb_bin, bae_bin)))
    # device_get hands back a read-only view; callers (GAE correction)
    # write into the reconstruction in place.
    return recon if recon.flags.writeable else recon.copy()


def run_recon_stage(hbae_params: dict, bae_params: list,
                    hyperblocks: np.ndarray) -> np.ndarray:
    """Unquantized AE reconstruction (``reconstruct_ae(quantize_latents=
    False)``)."""
    fn = _CACHE.get("recon_frontend", _recon_frontend)
    return np.asarray(jax.device_get(
        fn(hbae_params, bae_params, jnp.asarray(hyperblocks))))


# ---------------------------------------------------------------------------
# mesh-sharded stage programs (shard_map over the hyper-block data axis)
# ---------------------------------------------------------------------------
# One shard processes EXACTLY one stripe: the caller stacks ``n_shards``
# equal-width stripes (parallel.mesh_exec.plan_shard_groups), so the
# per-shard block shapes equal the single-device per-stripe shapes and the
# per-shard math is bit-identical to the unsharded path — which is what makes
# sharded archives byte-identical to single-device archives.  Params ride in
# replicated (in_spec P()); latents stay device-resident and sharded between
# the encode and decode programs (no gather in the middle).

def _mesh_axis() -> str:
    from repro.core.options import MESH_AXIS
    return MESH_AXIS


def _sharded_program(name: str, fn: Callable, mesh, in_specs, out_specs
                     ) -> Callable:
    """Build-or-fetch one shard_map-wrapped jitted program.  The retrace
    counter name carries the shard count so sharded and unsharded traces are
    distinguishable in ``retrace_counts()``."""
    from jax.experimental.shard_map import shard_map
    axis = _mesh_axis()
    counted_name = f"{name}@{axis}{mesh.shape[axis]}"
    wrapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return _CACHE.get(counted_name, wrapped, mesh=mesh)


def run_compress_stage_sharded_async(hbae_params: dict, bae_params: list,
                                     stacked: np.ndarray, hb_bin: float,
                                     bae_bin: float, mesh):
    """Dispatch the fused compress front-end for ONE shard group: ``stacked``
    is ``n_shards`` equal-width stripes concatenated on the hyper-block axis
    (shape ``(n_shards * w, k, d)``).  Each shard runs the same two fused
    programs the single-device path runs on a ``(w, k, d)`` stripe; the
    quantized latents stay sharded on device between them.  Returns handles
    for ``fetch_compress_stage``.
    """
    from jax.sharding import PartitionSpec as P
    axis = _mesh_axis()
    shard = P(axis)
    enc = _sharded_program(
        "encode_frontend", _encode_frontend, mesh,
        (P(), P(), shard, P(), P()), (shard, shard))
    dec = _sharded_program(
        "decode_backend", _decode_backend, mesh,
        (P(), P(), shard, shard, P(), P()), shard)
    x = jnp.asarray(stacked)
    q_lh, q_lbs = enc(hbae_params, bae_params, x, hb_bin, bae_bin)
    recon = dec(hbae_params, bae_params, q_lh, q_lbs, hb_bin, bae_bin)
    return q_lh, q_lbs, recon


def run_compress_stage_sharded(hbae_params: dict, bae_params: list,
                               stacked: np.ndarray, hb_bin: float,
                               bae_bin: float, mesh
                               ) -> tuple[np.ndarray, list[np.ndarray],
                                          np.ndarray]:
    """Blocking sharded compress front-end for one shard group; numpy
    results cover the whole group (callers slice per stripe).  Stage time is
    recorded under ``ae_encode_sharded`` with ``calls`` = shard count, so
    ``stage_stats()`` reports per-shard seconds as ``seconds / calls``."""
    axis = _mesh_axis()
    n_shards = int(mesh.shape[axis])
    t0 = time.perf_counter()
    out = fetch_compress_stage(run_compress_stage_sharded_async(
        hbae_params, bae_params, stacked, hb_bin, bae_bin, mesh))
    record_stage("ae_encode_sharded", time.perf_counter() - t0,
                 int(np.asarray(stacked).size), calls=n_shards)
    counter_max("mesh.shards", n_shards)
    counter_add("mesh.sharded_groups")
    return out


def run_decompress_stage_sharded(hbae_params: dict, bae_params: list,
                                 q_lh: np.ndarray, q_lbs: list,
                                 hb_bin: float, bae_bin: float, mesh
                                 ) -> np.ndarray:
    """Fused dequantize+decode back-end over the mesh: hyper-block rows are
    zero-padded to an even shard split (padded rows decode to garbage and
    are sliced off; real rows decode shard-locally).  ``q_lbs`` rows group
    ``k`` blocks per hyper-block, so their padded leading axes stay aligned
    with ``q_lh``'s shard boundaries by construction.
    """
    from jax.sharding import PartitionSpec as P
    axis = _mesh_axis()
    n_shards = int(mesh.shape[axis])
    q_lh = _as_q32(q_lh)
    q_lbs = [_as_q32(q) for q in q_lbs]
    n = q_lh.shape[0]
    pad = (-n) % n_shards
    if pad:
        q_lh = np.concatenate(
            [q_lh, np.zeros((pad,) + q_lh.shape[1:], q_lh.dtype)], axis=0)
        padded_lbs = []
        for q in q_lbs:
            k = q.shape[0] // n
            padded_lbs.append(np.concatenate(
                [q, np.zeros((pad * k,) + q.shape[1:], q.dtype)], axis=0))
        q_lbs = padded_lbs
    shard = P(axis)
    dec = _sharded_program(
        "decode_backend", _decode_backend, mesh,
        (P(), P(), shard, shard, P(), P()), shard)
    t0 = time.perf_counter()
    recon = np.asarray(jax.device_get(
        dec(hbae_params, bae_params, jnp.asarray(q_lh),
            [jnp.asarray(q) for q in q_lbs], hb_bin, bae_bin)))
    recon = recon[:n]
    record_stage("ae_decode_sharded", time.perf_counter() - t0,
                 int(recon.size), calls=n_shards)
    counter_max("mesh.shards", n_shards)
    return recon if recon.flags.writeable else recon.copy()
