"""GAE — Guaranteed-error-bound post-processing (paper Sec. II-D, Algorithm 1).

Given original blocks x, autoencoder reconstructions x^R and a user bound tau,
GAE projects each block residual onto a PCA basis U (fit on the residuals of
the whole dataset), keeps the top-M *quantized* coefficients per block with M
minimal such that ||x - x^G||_2 <= tau, and corrects x^G = x^R + U_s c_q.

Two implementations, proven equivalent by tests:

* ``gae_reference_loop`` — a literal per-block port of the paper's Algorithm 1
  (serial ``while delta > tau: M += 1`` loop).  The oracle.
* ``gae_select`` — the TPU-native adaptation: because U is orthonormal, the
  post-correction error decomposes exactly in coefficient space as

      err^2(M) = sum_{k>M} c_(k)^2  +  sum_{k<=M} (c_(k) - q(c_(k)))^2

  over magnitude-sorted coefficients, so minimal M for EVERY block in a batch
  falls out of one projection (MXU matmul), one sort, two cumulative sums and
  one comparison — branch-free and batched.  This replaces the paper's serial
  re-quantize/re-reconstruct loop (GPU/CPU-style) with a one-shot form.

Distribution: ``fit_pca_basis(..., axis_name=...)`` computes the residual
covariance locally and ``psum``s the D x D matrix across the data axis, so the
basis is exact over the global dataset with O(D^2) communication independent of
dataset size.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import GuaranteeUnsatisfiable
from repro.core.quantization import dequantize, quantize

Array = jax.Array


# ---------------------------------------------------------------------------
# PCA basis
# ---------------------------------------------------------------------------

def fit_pca_basis(residuals: Array, axis_name: Optional[str] = None) -> Array:
    """PCA basis of block residuals.

    residuals: (N, D).  Returns U (D, D) with eigenvectors as COLUMNS, sorted
    by descending eigenvalue; coefficients are c = U^T r (paper Eq. 9).
    """
    r = residuals.astype(jnp.float32)
    cov = r.T @ r                                     # (D, D)
    if axis_name is not None:
        cov = jax.lax.psum(cov, axis_name)
    # eigh returns ascending eigenvalues; flip to descending.
    _, vecs = jnp.linalg.eigh(cov)
    return vecs[:, ::-1]


# ---------------------------------------------------------------------------
# one-shot batched selection (TPU adaptation)
# ---------------------------------------------------------------------------

class GAESelection(NamedTuple):
    m: Array            # (N,)   minimal M per block (0 = block already within tau)
    order: Array        # (N, D) basis indices sorted by coefficient magnitude desc
    q_sorted: Array     # (N, D) quantized (int) coefficients in sorted order
    corrected: Array    # (N, D) corrected residual reconstruction  U_s c_q
    err: Array          # (N,)   actual l2 error after correction
    ok: Array           # (N,)   bool, err <= tau achievable with this bin size


def gae_select(residuals: Array, basis: Array, tau: float, bin_size: float,
               *, use_kernel: bool = False) -> GAESelection:
    """Batched minimal-M selection. residuals: (N, D); basis: (D, D)."""
    r = residuals.astype(jnp.float32)
    if use_kernel:
        from repro.kernels.gae_project import ops as gp_ops
        c, c2 = gp_ops.gae_project(r, basis)
    else:
        c = r @ basis                                  # (N, D) coefficients
        c2 = jnp.square(c)

    order = jnp.argsort(-c2, axis=-1)                  # descending magnitude
    c_sorted = jnp.take_along_axis(c, order, axis=-1)
    c2_sorted = jnp.take_along_axis(c2, order, axis=-1)

    q_sorted = quantize(c_sorted, bin_size)
    deq = dequantize(q_sorted, bin_size)
    qerr2 = jnp.square(c_sorted - deq)

    total = jnp.sum(c2_sorted, axis=-1, keepdims=True)         # err^2(0) = ||r||^2
    tail2 = total - jnp.cumsum(c2_sorted, axis=-1)              # err tail for M=1..D
    kept2 = jnp.cumsum(qerr2, axis=-1)                          # quant err for M=1..D
    err2 = jnp.concatenate([total, tail2 + kept2], axis=-1)     # index M = 0..D

    ok_any = err2 <= tau * tau
    m = jnp.argmax(ok_any, axis=-1)                             # first M satisfying
    ok = jnp.any(ok_any, axis=-1)
    m = jnp.where(ok, m, residuals.shape[-1])                   # fall back to full-D

    # corrected residual: U @ (masked quantized coeffs un-permuted).  The
    # un-permute is a row-local GATHER via the inverse permutation — a row
    # scatter (.at[].set) here makes GSPMD replicate the whole coefficient
    # matrix across the mesh (§Perf gae_select iteration 2).
    keep = jnp.arange(residuals.shape[-1])[None, :] < m[:, None]
    deq_masked = jnp.where(keep, deq, 0.0)
    inv_order = jnp.argsort(order, axis=-1)
    c_hat = jnp.take_along_axis(deq_masked, inv_order, axis=-1)
    corrected = c_hat @ basis.T
    err = jnp.linalg.norm(r - corrected, axis=-1)
    return GAESelection(m=m, order=order, q_sorted=q_sorted, corrected=corrected,
                        err=err, ok=ok)


def gae_apply(x: Array, x_r: Array, basis: Array, tau: float, bin_size: float,
              *, use_kernel: bool = False) -> tuple[Array, GAESelection]:
    """Corrected reconstruction x^G (paper Eq. 10) for a batch of blocks."""
    sel = gae_select(x - x_r, basis, tau, bin_size, use_kernel=use_kernel)
    return x_r + sel.corrected, sel


def select_host(residuals: np.ndarray, basis: np.ndarray, tau: float,
                bin_size: float) -> GAESelection:
    """Numpy twin of ``gae_select`` for the host-side encoder on the CPU
    backend, where XLA's row sorts run far slower than numpy's.  Same math,
    same rounding (round-half-to-even, float32 dequantize), same fields —
    equivalence is pinned by tests against ``gae_select``."""
    r = np.asarray(residuals, np.float32)
    u = np.asarray(basis, np.float32)
    d = r.shape[-1]
    c = r @ u
    c2 = np.square(c)
    order = np.argsort(-c2, axis=-1)
    c_sorted = np.take_along_axis(c, order, axis=-1)
    c2_sorted = np.take_along_axis(c2, order, axis=-1)
    q_sorted = np.round(c_sorted / bin_size).astype(np.int32)
    deq = q_sorted.astype(np.float32) * np.float32(bin_size)
    qerr2 = np.square(c_sorted - deq)
    total = c2_sorted.sum(axis=-1, keepdims=True)
    tail2 = total - np.cumsum(c2_sorted, axis=-1)
    kept2 = np.cumsum(qerr2, axis=-1)
    err2 = np.concatenate([total, tail2 + kept2], axis=-1)
    ok_any = err2 <= tau * tau
    m = np.argmax(ok_any, axis=-1)
    ok = ok_any.any(axis=-1)
    m = np.where(ok, m, d)
    keep = np.arange(d)[None, :] < m[:, None]
    c_hat = np.zeros_like(deq)
    np.put_along_axis(c_hat, order, np.where(keep, deq, np.float32(0.0)),
                      axis=-1)
    corrected = c_hat @ u.T
    err = np.linalg.norm(r - corrected, axis=-1)
    return GAESelection(m=m, order=order, q_sorted=q_sorted,
                        corrected=corrected, err=err, ok=ok)


# ---------------------------------------------------------------------------
# literal Algorithm 1 (oracle; host-side, per block)
# ---------------------------------------------------------------------------

def gae_reference_loop(x: np.ndarray, x_r: np.ndarray, basis: np.ndarray,
                       tau: float, bin_size: float) -> tuple[np.ndarray, list[int]]:
    """Direct port of paper Algorithm 1. x, x_r: (N, D); returns (x^G, M list)."""
    x = np.asarray(x, np.float32)
    x_r = np.asarray(x_r, np.float32)
    u = np.asarray(basis, np.float32)
    out = x_r.copy()
    ms = []
    for i in range(x.shape[0]):
        xi, xr = x[i], x_r[i]
        delta = float(np.linalg.norm(xi - xr))
        if delta <= tau:
            ms.append(0)
            continue
        c = u.T @ (xi - xr)                            # line 6
        order = np.argsort(-np.square(c))              # sort c_k^2 desc
        m = 1
        while True:                                    # lines 8-14
            sel = order[:m]
            cq = np.round(c[sel] / bin_size) * bin_size
            xg = xr + u[:, sel] @ cq
            delta = float(np.linalg.norm(xi - xg))
            if delta <= tau or m >= x.shape[1]:
                break
            m += 1
        out[i] = xg
        ms.append(m)
    return out, ms


# ---------------------------------------------------------------------------
# host-side encoder with HARD guarantee (per-block bin fallback)
# ---------------------------------------------------------------------------

class GAEBlockCode(NamedTuple):
    m: int                  # number of kept coefficients
    indices: np.ndarray     # (m,) basis indices (int32), ASCENDING index order
    qcoeffs: np.ndarray     # (m,) quantized ints at bin_size / 2**bin_exp
    bin_exp: int            # per-block bin refinement exponent (usually 0)


def gae_encode_blocks(x: np.ndarray, x_r: np.ndarray, basis: np.ndarray,
                      tau: float, bin_size: float,
                      max_refine: int = 20) -> tuple[np.ndarray, list[GAEBlockCode]]:
    """Encode every block with a HARD ||x - x^G||_2 <= tau guarantee.

    Uses the one-shot vectorized selection, then verifies the realized error per
    block against the *actual* reconstruction (guarding numerical non-
    orthonormality of the eigh basis) and, for any block that cannot meet tau at
    the global bin size, halves the bin (per-block ``bin_exp``) until it does.
    With a full-rank basis the quantization error goes to 0 under refinement;
    if the budget is exhausted with ``err > tau`` (rank-deficient basis,
    ``max_refine`` too small), raises ``GuaranteeUnsatisfiable`` instead of
    emitting a block that violates the bound the caller would then claim.

    Code construction is vectorized (errors, membership masks and the
    ascending-index extraction are whole-batch numpy passes); the per-block
    Python work is only the two slices + namedtuple per code, and the repair
    loop runs solely for blocks whose verified error still exceeds ``tau``.
    """
    from repro.core import exec as exec_mod

    x = np.asarray(x, np.float32)
    x_r = np.asarray(x_r, np.float32)
    u = np.asarray(basis, np.float32)
    n, d = x.shape

    if jax.default_backend() == "cpu":
        # host twin: numpy row sorts beat XLA CPU's by a wide margin, and the
        # encoder is host-side anyway (see select_host)
        sel = select_host(x - x_r, u, tau, bin_size)
    else:
        select = exec_mod.cache().get("gae_select", gae_select,
                                      static_argnames=("use_kernel",))
        sel = jax.device_get(select(jnp.asarray(x - x_r), jnp.asarray(u),
                                    tau, bin_size))
    out = x_r + np.asarray(sel.corrected)

    # batch extraction in ascending index order: scatter the kept-coefficient
    # membership and quantized values from sorted-magnitude space back to
    # index space, then one np.nonzero walks every block's set in index order.
    ms = np.asarray(sel.m, np.int64)
    order64 = np.asarray(sel.order, np.int64)
    keep = np.arange(d)[None, :] < ms[:, None]            # sorted-mag space
    mask = np.zeros((n, d), bool)
    np.put_along_axis(mask, order64, keep, axis=1)
    q_idx_space = np.zeros((n, d), np.int32)
    np.put_along_axis(q_idx_space, order64,
                      np.asarray(sel.q_sorted, np.int32), axis=1)
    rows, cols = np.nonzero(mask)                          # row-major: ascending
    idx_all = cols.astype(np.int32)
    q_all = q_idx_space[rows, cols].astype(np.int64)
    bounds = np.zeros(n + 1, np.int64)
    np.cumsum(mask.sum(axis=1), out=bounds[1:])
    errs = np.linalg.norm(x - out, axis=1)

    codes: list[GAEBlockCode] = []
    ms_list = ms.tolist()
    bounds_list = bounds.tolist()
    for i in range(n):
        m = ms_list[i]
        bin_exp = 0
        b = bin_size
        idx = idx_all[bounds_list[i]:bounds_list[i + 1]]
        q = q_all[bounds_list[i]:bounds_list[i + 1]]
        err = errs[i]
        # verify & repair (numerical safety + coarse-bin fallback)
        while err > tau and bin_exp < max_refine:
            if m < d:
                m = min(d, m + max(1, d // 32))
            else:
                bin_exp += 1
                b = bin_size / (2 ** bin_exp)
            c = u.T @ (x[i] - x_r[i])
            order = np.argsort(-np.square(c))
            idx = np.sort(order[:m]).astype(np.int32)
            q = np.round(c[idx] / b).astype(np.int64)
            rec = x_r[i] + u[:, idx] @ (q.astype(np.float32) * b)
            err = float(np.linalg.norm(x[i] - rec))
            out[i] = rec
        if err > tau:
            raise GuaranteeUnsatisfiable(block=i, err=err, tau=tau,
                                         max_refine=max_refine)
        codes.append(GAEBlockCode(m, idx, q, bin_exp))
    return out, codes


def gae_decode_blocks(x_r: np.ndarray, basis: np.ndarray, codes: list[GAEBlockCode],
                      bin_size: float) -> np.ndarray:
    """Inverse of gae_encode_blocks given the AE reconstruction x^R.

    Vectorized: all blocks' dequantized coefficients scatter into one dense
    (N, D) matrix (index sets are unique per block, so plain fancy-index
    assignment is exact) and the correction is a single ``@ basis.T`` matmul
    instead of a per-block Python loop.
    """
    u = np.asarray(basis, np.float32)
    out = np.asarray(x_r, np.float32).copy()
    if not codes:
        return out
    ms = np.fromiter((c.m for c in codes), np.int64, len(codes))
    if not ms.sum():
        return out
    rows = np.repeat(np.arange(len(codes)), ms)
    cols = np.concatenate([c.indices for c in codes]).astype(np.int64)
    qs = np.concatenate([c.qcoeffs for c in codes]).astype(np.float32)
    binexps = np.fromiter((c.bin_exp for c in codes), np.int64, len(codes))
    b_vals = (bin_size / np.exp2(binexps.astype(np.float64)))[rows]
    coeffs = np.zeros(out.shape, np.float32)
    coeffs[rows, cols] = qs * b_vals.astype(np.float32)
    out += coeffs @ u.T
    return out
