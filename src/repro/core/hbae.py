"""Hyper-Block AutoEncoder (HBAE) — paper Sec. II-B.

Encoding path (per hyper-block of k blocks, each block flattened to ``in_dim``):
  1. each block -> 2-layer FC encoder (ReLU middle) -> embedding e_i in R^emb
  2. e~ = Atten(LayerNorm(e)) + e                       (Eq. 6)
  3. flatten (k, emb) -> FC -> latent L_h in R^latent

Decoding mirrors it: L_h -> FC -> (k, emb) -> same attention block form ->
per-block 2-layer FC decoder -> reconstructed blocks y_i.

Shapes: x is (B, k, in_dim); latent is (B, latent); output is (B, k, in_dim).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.attention import (attention_block, attention_block_init,
                                  linear, linear_init)

Array = jax.Array


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class HbaeMeta:
    k: int
    emb: int
    use_attention: bool


def mlp2_init(key: Array, d_in: int, d_hidden: int, d_out: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {"fc1": linear_init(k1, d_in, d_hidden), "fc2": linear_init(k2, d_hidden, d_out)}


def mlp2(params: dict, x: Array) -> Array:
    return linear(params["fc2"], jax.nn.relu(linear(params["fc1"], x)))


def hbae_init(key: Array, *, in_dim: int, k: int, emb: int = 128,
              hidden: int = 256, latent: int = 128, heads: int = 1,
              use_attention: bool = True) -> dict:
    """``use_attention=False`` builds the 'HBAE-woa' ablation of paper Fig. 5."""
    keys = jax.random.split(key, 6)
    params = {
        "enc": mlp2_init(keys[0], in_dim, hidden, emb),
        "to_latent": linear_init(keys[1], k * emb, latent),
        "from_latent": linear_init(keys[2], latent, k * emb),
        "dec": mlp2_init(keys[3], emb, hidden, in_dim),
        "meta": HbaeMeta(k=k, emb=emb, use_attention=use_attention),
    }
    if use_attention:
        params["enc_attn"] = attention_block_init(keys[4], emb, heads=heads)
        params["dec_attn"] = attention_block_init(keys[5], emb, heads=heads)
    return params


def hbae_encode(params: dict, x: Array, *, use_kernel: bool = False) -> Array:
    """(B, k, in_dim) -> (B, latent)."""
    meta = params["meta"]
    e = mlp2(params["enc"], x)                           # (B, k, emb)
    if meta.use_attention:
        e = attention_block(params["enc_attn"], e, use_kernel=use_kernel)
    flat = e.reshape(e.shape[0], -1)                      # (B, k*emb)
    return linear(params["to_latent"], flat)


def hbae_decode(params: dict, latent: Array, *, use_kernel: bool = False) -> Array:
    """(B, latent) -> (B, k, in_dim)."""
    meta = params["meta"]
    k, emb = meta.k, meta.emb
    e = linear(params["from_latent"], latent).reshape(latent.shape[0], k, emb)
    if meta.use_attention:
        e = attention_block(params["dec_attn"], e, use_kernel=use_kernel)
    return mlp2(params["dec"], e)


def hbae_apply(params: dict, x: Array, *, use_kernel: bool = False) -> tuple[Array, Array]:
    """Returns (reconstruction y, latent L_h)."""
    latent = hbae_encode(params, x, use_kernel=use_kernel)
    y = hbae_decode(params, latent, use_kernel=use_kernel)
    return y, latent
