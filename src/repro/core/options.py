"""Unified compress configuration surface: ``CompressOptions``.

After the streaming (PR 3) and fault-tolerance (PR 4) layers landed, the
compress entry points had grown three divergent configuration surfaces:

* ``HierarchicalCompressor.compress(hyperblocks, tau=..., chunk_hyperblocks=...)``
* ``stream_compress(comp, hb, tau=..., chunk_hyperblocks=..., queue_depth=...,
  fault_tolerance=FaultTolerance(...), chaos=ChaosInjector(...))``
* ``launch/compress.py --tau/--chunk-hyperblocks/--stream/--queue-depth/
  --retries/--stage-deadline/--chaos`` argv flags

each spelling the same knobs differently.  ``CompressOptions`` is the single
frozen configuration object all three accept; the old kwarg surfaces remain
as thin shims that emit ``DeprecationWarning`` and delegate (see
``HierarchicalCompressor.compress`` / ``stream_compress``).

Validation happens at CONSTRUCTION time and raises a typed
:class:`~repro.core.errors.ConfigError` — a zero-width chunk or a mesh
without the hyper-block axis fails here, in one obvious place, instead of as
a mid-run XLA shape crash deep inside a sharded trace.

The ``mesh`` field is deliberately loose about types so this module stays
import-light (no jax device initialization at option-construction time):

* ``None``  — single-device execution (the default),
* ``int``   — shard over that many devices of a 1-D ``("hb",)`` mesh built
  by ``repro.parallel.mesh_exec.resolve_mesh`` at run time,
* ``jax.sharding.Mesh`` — used as-is; must carry the hyper-block data axis
  ``repro.parallel.mesh_exec.MESH_AXIS`` (``"hb"``) and may not shard any
  other axis (the compress pipeline is data-parallel only).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.errors import ConfigError

#: Name of the hyper-block data axis every compress mesh must carry.  Lives
#: here (not in ``parallel.mesh_exec``) so option validation never imports
#: jax; ``mesh_exec`` re-exports it.
MESH_AXIS = "hb"


@dataclasses.dataclass(frozen=True)
class CompressOptions:
    """One frozen configuration object for a compress run (batch or stream).

    Fields mirror the union of the three legacy surfaces:

    * ``tau`` — per-GAE-block l2 error bound; ``None`` disables the GAE
      guarantee stage entirely.
    * ``chunk_hyperblocks`` — requested stripe width (hyper-blocks per
      independently-decodable archive chunk).  The pipeline may round it UP
      for GAE block alignment; it is never silently clamped up from zero —
      a non-positive width is a :class:`ConfigError` here.
    * ``stream`` — route through the pipelined ``repro.stream`` path.
    * ``queue_depth`` — streaming inter-stage queue bound (backpressure).
    * ``retries`` — per-item transient-failure retries (enables the
      fault-tolerance ladder + quarantine fallback when set).
    * ``stage_deadline_s`` — per-attempt watchdog deadline on the streaming
      compute stages (implies fault tolerance).
    * ``chaos_seed`` — seeded live fault injection (implies fault tolerance).
    * ``mesh`` — device mesh for the sharded stage pipeline (see module
      docstring for the accepted forms).
    """
    tau: Optional[float] = None
    chunk_hyperblocks: int = 64
    stream: bool = False
    queue_depth: int = 2
    retries: Optional[int] = None
    stage_deadline_s: Optional[float] = None
    chaos_seed: Optional[int] = None
    mesh: Optional[object] = None     # None | int | jax.sharding.Mesh

    def __post_init__(self):
        if not isinstance(self.chunk_hyperblocks, int) \
                or isinstance(self.chunk_hyperblocks, bool):
            raise ConfigError(
                f"chunk_hyperblocks must be an int, got "
                f"{type(self.chunk_hyperblocks).__name__}")
        if self.chunk_hyperblocks < 1:
            raise ConfigError(
                f"chunk_hyperblocks must be >= 1, got "
                f"{self.chunk_hyperblocks} (a zero-width stripe can never "
                f"tile the hyper-block axis)")
        if self.tau is not None and not self.tau > 0:
            raise ConfigError(f"tau must be > 0 (or None to disable the "
                              f"guarantee stage), got {self.tau}")
        if self.queue_depth < 1:
            raise ConfigError(f"queue_depth must be >= 1, got "
                              f"{self.queue_depth}")
        if self.retries is not None and self.retries < 0:
            raise ConfigError(f"retries must be >= 0, got {self.retries}")
        if self.stage_deadline_s is not None and not self.stage_deadline_s > 0:
            raise ConfigError(f"stage_deadline_s must be > 0, got "
                              f"{self.stage_deadline_s}")
        self._validate_mesh()

    def _validate_mesh(self) -> None:
        mesh = self.mesh
        if mesh is None:
            return
        if isinstance(mesh, bool):
            raise ConfigError("mesh must be None, an int shard count, or a "
                              "jax.sharding.Mesh — got a bool")
        if isinstance(mesh, int):
            if mesh < 1:
                raise ConfigError(f"mesh shard count must be >= 1, got {mesh}")
            return
        # Duck-typed Mesh check (axis_names/shape) so constructing options
        # never imports jax; a real Mesh always has both attributes.
        axis_names = getattr(mesh, "axis_names", None)
        shape = getattr(mesh, "shape", None)
        if axis_names is None or shape is None:
            raise ConfigError(
                f"mesh must be None, an int shard count, or a "
                f"jax.sharding.Mesh, got {type(mesh).__name__}")
        if MESH_AXIS not in axis_names:
            raise ConfigError(
                f"compress mesh is missing the hyper-block data axis "
                f"{MESH_AXIS!r} (axes: {tuple(axis_names)}) — the stage "
                f"pipeline shards over {MESH_AXIS!r} only")
        for name in axis_names:
            if name != MESH_AXIS and shape[name] != 1:
                raise ConfigError(
                    f"compress mesh axis {name!r} has size {shape[name]}; "
                    f"only the {MESH_AXIS!r} data axis may be sharded "
                    f"(size-1 auxiliary axes are fine)")

    # -- derived views -------------------------------------------------------
    def fault_tolerant(self) -> bool:
        """True when any fault-tolerance knob is set (retries / deadline /
        chaos) — the streaming path then arms the retry→quarantine ladder."""
        return (self.retries is not None or self.stage_deadline_s is not None
                or self.chaos_seed is not None)

    def mesh_shards(self) -> int:
        """Requested shard count WITHOUT resolving devices (0 = unsharded);
        a concrete Mesh reports its ``hb``-axis size."""
        if self.mesh is None:
            return 0
        if isinstance(self.mesh, int):
            return self.mesh
        return int(self.mesh.shape[MESH_AXIS])

    def replace(self, **changes) -> "CompressOptions":
        """Functional update (re-validates)."""
        return dataclasses.replace(self, **changes)


def resolve_options(options: Optional[CompressOptions],
                    legacy: dict, *, caller: str,
                    defaults: Optional[CompressOptions] = None
                    ) -> CompressOptions:
    """Back-compat shim used by the compress entry points.

    ``legacy`` maps CompressOptions field names to values the caller received
    through its old kwarg surface (entries whose value is ``None``/unset are
    dropped by the caller before passing them here).  Passing BOTH an options
    object and legacy kwargs is an error; legacy kwargs alone emit one
    ``DeprecationWarning`` and are folded into a fresh options object.
    """
    if options is not None:
        if legacy:
            raise ConfigError(
                f"{caller}: pass either a CompressOptions object or legacy "
                f"kwargs {sorted(legacy)}, not both")
        return options
    base = defaults if defaults is not None else CompressOptions()
    if legacy:
        warnings.warn(
            f"{caller}: the {sorted(legacy)} kwarg surface is deprecated; "
            f"pass a repro.core.options.CompressOptions instead",
            DeprecationWarning, stacklevel=3)
        return dataclasses.replace(base, **legacy)
    return base
