"""End-to-end compressor pipeline (paper Fig. 1).

``HierarchicalCompressor`` ties together:
  hyper-block AE (coarse)  ->  block-wise residual AE(s) (fine)  ->
  GAE PCA post-processing (guaranteed per-block l2 bound)  ->
  quantization + Huffman + index-bitmask/zlib bitstream.

The object is fit on (a training split of) the data, then ``compress`` returns
an ``Archive`` whose ``total_bytes()`` is the honest storage cost (AE latents +
GAE coefficients + index sets + per-block headers).  Model weights and the PCA
basis are excluded by default — the paper's ratio accounting amortizes them
("we considered the latent spaces of both autoencoders, as well as the PCA
coefficients and corresponding index information", Sec. III-C); pass
``include_model_cost=True`` to count them too.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bae as bae_mod
from repro.core import entropy, gae
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core import training
from repro.core.errors import (ArchiveError, ChecksumMismatch, ChunkDamage,
                               ConfigError, DamageReport,
                               GuaranteeUnsatisfiable, MalformedStream)
from repro.core.options import CompressOptions, resolve_options

Array = jax.Array

#: Sentinel distinguishing "kwarg not passed" from an explicit ``None`` on
#: the deprecated ``compress(tau=..., chunk_hyperblocks=...)`` surface.
_UNSET = object()


@dataclasses.dataclass
class CompressorConfig:
    block_elems: int                 # flattened AE block size
    k: int                           # blocks per hyper-block
    emb: int = 128
    hidden: int = 256
    hb_latent: int = 128             # paper: 128 S3D / 64 E3SM,XGC
    bae_hidden: int = 256
    bae_latent: int = 16             # paper: 16 for all datasets
    heads: int = 1
    use_attention: bool = True       # False => 'HBAE-woa' ablation
    use_bae: bool = True             # False => 'HBAE' ablation
    n_bae_stages: int = 1            # 2 => 'StackAE' ablation
    hb_bin: float = 0.005
    bae_bin: float = 0.005
    gae_bin: float = 0.01
    gae_block_elems: Optional[int] = None   # GAE may re-block (paper Sec. II-D)
    epochs_hbae: int = 30
    epochs_bae: int = 30
    batch: int = 64
    lr: float = 1e-3


@dataclasses.dataclass
class ArchiveChunk:
    """One hyper-block stripe: every stream needed to decode hyper-blocks
    ``[hb_start, hb_start + n_hyperblocks)`` independently of other chunks.

    A non-empty ``verbatim_blob`` marks a QUARANTINED stripe: the learned
    encoder could not ship it (exhausted retries or an unsatisfiable
    guarantee), so the payload is the deflate-packed raw float32 stripe
    itself — losslessly decodable, hence trivially within any tau — and all
    latent/GAE streams are absent (``hb_stream is None``).
    """
    hb_start: int
    n_hyperblocks: int
    hb_stream: Optional[entropy.HuffmanStream]
    bae_streams: list[entropy.HuffmanStream]
    gae_coeff_stream: Optional[entropy.HuffmanStream]
    gae_index_blob: bytes
    gae_binexp_blob: bytes
    verbatim_blob: bytes = b""


@dataclasses.dataclass
class Archive:
    """Compressed representation, striped into independently-decodable chunks.

    ``chunks`` entries may be ``None`` after a tolerant container read
    (``archive_io.read_archive(strict=False)``): the stripe failed its digest
    or framing checks and ``chunk_errors[i]`` holds the reason.
    """
    n_hyperblocks: int
    n_values: int                    # original float32 count
    chunk_hyperblocks: int           # stripe width (hyper-blocks per chunk)
    gae_dim: int                     # PCA basis dimension (0 = no GAE section)
    chunks: list[Optional[ArchiveChunk]]
    chunk_errors: dict[int, str] = dataclasses.field(default_factory=dict)
    _size_cache: Optional[int] = dataclasses.field(
        default=None, repr=False, compare=False)

    def verbatim_chunks(self) -> list[int]:
        """Indices of quarantined (lossless verbatim-fallback) chunks."""
        return [i for i, c in enumerate(self.chunks)
                if c is not None and c.verbatim_blob]

    def compressed_bytes(self) -> int:
        """Honest on-disk cost: the exact size of the serialized container
        (magic, section table, digests, framing — everything).  Computed from
        the section framing arithmetic (no full serialize) and cached, so
        ``compression_ratio`` is O(sections) once instead of O(archive) per
        query; mutators must call ``invalidate_size_cache``."""
        if self._size_cache is None:
            from repro.runtime import archive_io   # runtime owns the container
            self._size_cache = archive_io.serialized_size(self)
        return self._size_cache

    def invalidate_size_cache(self) -> None:
        self._size_cache = None

    def compression_ratio(self, include_model_bytes: int = 0) -> float:
        return (self.n_values * 4) / (self.compressed_bytes() + include_model_bytes)


@dataclasses.dataclass
class _VerbatimStripe:
    """Decoded form of a quarantined chunk: the raw hyper-blocks."""
    data: np.ndarray


MODEL_FORMAT = "repro-compressor-v2"

# Static (non-array) param-tree leaves that the manifest records by name +
# field dict instead of pickling.  Anything else non-array fails save loudly.
def _static_registry() -> dict:
    from repro.core.attention import AttnMeta
    from repro.core.hbae import HbaeMeta
    return {"AttnMeta": AttnMeta, "HbaeMeta": HbaeMeta}


def _flatten_params(obj, prefix: str, leaves: list, statics: dict) -> None:
    """Walk dict/list param trees into (path, array) leaves; registered static
    dataclasses are recorded as JSON-able entries in ``statics``."""
    if isinstance(obj, dict):
        for key in sorted(obj):
            _flatten_params(obj[key], f"{prefix}/{key}" if prefix else key,
                            leaves, statics)
    elif isinstance(obj, (list, tuple)):
        for i, item in enumerate(obj):
            _flatten_params(item, f"{prefix}/{i}" if prefix else str(i),
                            leaves, statics)
    elif type(obj).__name__ in _static_registry():
        statics[prefix] = {"class": type(obj).__name__,
                           "fields": dataclasses.asdict(obj)}
    elif hasattr(obj, "shape") and hasattr(obj, "dtype"):
        leaves.append((prefix, np.asarray(obj)))
    else:
        raise TypeError(f"cannot serialize param leaf {prefix!r} "
                        f"of type {type(obj).__name__}")


def _assemble_params(entries: list, statics: dict) -> dict:
    """Rebuild the nested dict tree from (path, value) pairs + statics."""
    registry = _static_registry()
    root: dict = {}
    items = list(entries)
    for path, spec in statics.items():
        if spec.get("class") not in registry:
            raise MalformedStream(f"unknown static class {spec.get('class')!r}")
        items.append((path, registry[spec["class"]](**spec["fields"])))
    for path, value in items:
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
            if not isinstance(node, dict):
                raise MalformedStream(f"conflicting manifest paths at {path!r}")
        node[parts[-1]] = value
    return root


class HierarchicalCompressor:
    """fit / compress / decompress on hyper-block-shaped data (N, k, D)."""

    def __init__(self, config: CompressorConfig):
        self.cfg = config
        self.hbae_params: Optional[dict] = None
        self.bae_params: list[dict] = []
        self.basis: Optional[np.ndarray] = None

    # -- training ----------------------------------------------------------
    def fit(self, hyperblocks: np.ndarray, seed: int = 0,
            log: Optional[Callable] = None) -> "HierarchicalCompressor":
        cfg = self.cfg
        n, k, d = hyperblocks.shape
        assert k == cfg.k and d == cfg.block_elems, (hyperblocks.shape, cfg)
        key = jax.random.PRNGKey(seed)
        khb, *kbs = jax.random.split(key, 1 + max(cfg.n_bae_stages, 1))
        self.hbae_params = training.train_hbae(
            khb, hyperblocks, emb=cfg.emb, hidden=cfg.hidden, latent=cfg.hb_latent,
            heads=cfg.heads, use_attention=cfg.use_attention,
            epochs=cfg.epochs_hbae, batch=cfg.batch, lr=cfg.lr, seed=seed, log=log)
        if cfg.use_bae:
            y, _ = self._hbae_forward(hyperblocks)
            resid = (hyperblocks - y).reshape(n * k, d)
            self.bae_params = []
            for s in range(cfg.n_bae_stages):
                p = training.train_bae(kbs[s], resid, hidden=cfg.bae_hidden,
                                       latent=cfg.bae_latent, epochs=cfg.epochs_bae,
                                       batch=max(cfg.batch * 4, 256), lr=cfg.lr,
                                       seed=seed + s, log=log)
                self.bae_params.append(p)
                apply_fn = exec_mod.cache().get("bae_apply", bae_mod.bae_apply)
                r_hat, _ = apply_fn(p, jnp.asarray(resid))
                resid = resid - np.asarray(r_hat)
        return self

    # -- forward helpers ----------------------------------------------------
    def _stage_params(self) -> list[dict]:
        return self.bae_params if self.cfg.use_bae else []

    def _hbae_forward(self, hyperblocks: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        apply_fn = exec_mod.cache().get("hbae_apply", hbae_mod.hbae_apply)
        y, latent = apply_fn(self.hbae_params, jnp.asarray(hyperblocks))
        return np.asarray(y), np.asarray(latent)

    def reconstruct_ae(self, hyperblocks: np.ndarray,
                       quantize_latents: bool = True) -> np.ndarray:
        """AE-only reconstruction (through quantized latents when requested)."""
        cfg = self.cfg
        if quantize_latents:
            # same fused front-end + shared decode program as ``compress``
            _, _, recon = exec_mod.run_compress_stage(
                self.hbae_params, self._stage_params(), hyperblocks,
                cfg.hb_bin, cfg.bae_bin)
            return recon
        return exec_mod.run_recon_stage(self.hbae_params, self._stage_params(),
                                        hyperblocks)

    # -- PCA basis -----------------------------------------------------------
    def fit_basis(self, hyperblocks: np.ndarray, mesh=None) -> np.ndarray:
        """PCA basis of AE residuals at GAE block granularity.

        With a ``mesh`` (anything ``parallel.mesh_exec.resolve_mesh``
        accepts) the D x D residual covariance is computed shard-locally and
        ``psum``-ed over the hyper-block axis — O(D^2) communication
        regardless of N — via ``gae.fit_pca_basis(axis_name=...)``.
        """
        recon = self.reconstruct_ae(hyperblocks)
        resid = self._gae_view(hyperblocks - recon)
        if mesh is not None:
            from repro.parallel import mesh_exec
            resolved = mesh_exec.resolve_mesh(mesh)
            if resolved is not None:
                self.basis = np.asarray(
                    mesh_exec.fit_pca_basis_sharded(resid, resolved))
                return self.basis
        self.basis = np.asarray(gae.fit_pca_basis(jnp.asarray(resid)))
        return self.basis

    def _gae_view(self, blocks3d: np.ndarray) -> np.ndarray:
        """(N, k, D) -> (N_gae, D_gae): GAE may use a different block size."""
        d_gae = self.cfg.gae_block_elems or self.cfg.block_elems
        flat = blocks3d.reshape(-1)
        assert flat.size % d_gae == 0
        return flat.reshape(-1, d_gae)

    def _gae_unview(self, gae_blocks: np.ndarray, shape3d: tuple) -> np.ndarray:
        return gae_blocks.reshape(shape3d)

    # -- compress / decompress ----------------------------------------------
    def _chunk_width(self, requested: int, with_gae: bool) -> int:
        """Stripe width in hyper-blocks, aligned so every chunk covers a whole
        number of GAE blocks (chunks must decode independently).

        A non-positive request is a :class:`ConfigError` (it used to be
        silently clamped to 1, which hid caller bugs and produced archives
        with a different stripe width than asked for)."""
        cfg = self.cfg
        width = int(requested)
        if width < 1:
            raise ConfigError(
                f"chunk_hyperblocks must be >= 1, got {requested!r} (a "
                f"zero-width stripe can never tile the hyper-block axis)")
        if with_gae:
            d_gae = cfg.gae_block_elems or cfg.block_elems
            per_hb = cfg.k * cfg.block_elems
            align = d_gae // math.gcd(d_gae, per_hb)   # chunk width multiple
            width = ((width + align - 1) // align) * align
        return width

    def stripe_spans(self, n_hyperblocks: int, chunk_hyperblocks: int,
                     with_gae: bool) -> list[tuple[int, int]]:
        """``[(hb_start, n_hb), ...]`` stripe tiling of ``n_hyperblocks`` at
        the GAE-aligned chunk width.  The SAME tiling drives the batch
        compress loop, the streaming scheduler, and the streaming archive
        writer's up-front section table."""
        width = self._chunk_width(chunk_hyperblocks, with_gae=with_gae)
        return [(s, min(width, n_hyperblocks - s))
                for s in range(0, n_hyperblocks, width)]

    def encode_stripe_device(self, stripe: np.ndarray
                             ) -> tuple[np.ndarray, list[np.ndarray],
                                        np.ndarray]:
        """Device half of one stripe's encode: fused front-end + shared
        decode program on the stripe's hyper-blocks only."""
        return exec_mod.run_compress_stage(
            self.hbae_params, self._stage_params(), stripe,
            self.cfg.hb_bin, self.cfg.bae_bin)

    def encode_stripe_host(self, hb_start: int, stripe: np.ndarray,
                           q_lh: np.ndarray, q_lbs: list[np.ndarray],
                           recon: np.ndarray, tau: Optional[float],
                           gae_dim: int) -> ArchiveChunk:
        """Host half of one stripe's encode: GAE error-bound coding + chunk
        entropy coding, from the stripe's own data only.

        Both the batch ``compress`` loop and the streaming scheduler call
        exactly this function on exactly the same slices, which is what makes
        their chunk sections byte-identical by construction (not by floating-
        point luck across different batch shapes).
        """
        cfg = self.cfg
        k, d = cfg.k, cfg.block_elems
        codes: list[gae.GAEBlockCode] = []
        if tau is not None:
            d_gae = cfg.gae_block_elems or d
            gae_per_hb = (k * d) // d_gae
            with exec_mod.stage("gae_encode", stripe.size):
                x_gae = self._gae_view(stripe)
                r_gae = self._gae_view(recon)
                try:
                    _, codes = gae.gae_encode_blocks(x_gae, r_gae, self.basis,
                                                     tau, cfg.gae_bin)
                except GuaranteeUnsatisfiable as e:
                    # re-raise with the GLOBAL GAE block index so diagnostics
                    # are stripe-independent
                    raise GuaranteeUnsatisfiable(
                        block=hb_start * gae_per_hb + e.block, err=e.err,
                        tau=e.tau, max_refine=e.max_refine) from e
        with exec_mod.stage("entropy_encode", stripe.size):
            hb_stream = entropy.huffman_compress(q_lh)
            bae_streams = [entropy.huffman_compress(q_lb) for q_lb in q_lbs]
            coeff_stream = None
            index_blob = binexp_blob = b""
            if tau is not None:
                # GAEBlockCode stores indices/coefficients in ascending index
                # order — exactly the bitmask decode order, no per-code sort
                all_coeffs, index_sets, binexps = [], [], []
                for c in codes:
                    index_sets.append(c.indices)
                    all_coeffs.append(c.qcoeffs)
                    binexps.append(c.bin_exp)
                coeffs = (np.concatenate(all_coeffs) if all_coeffs else
                          np.zeros(0, np.int64))
                if coeffs.size:
                    coeff_stream = entropy.huffman_compress(coeffs)
                index_blob = entropy.encode_index_sets(index_sets, gae_dim)
                binexp_blob = entropy.zlib_pack(
                    np.asarray(binexps, np.uint8).tobytes())
        return ArchiveChunk(
            hb_start=hb_start, n_hyperblocks=stripe.shape[0],
            hb_stream=hb_stream, bae_streams=bae_streams,
            gae_coeff_stream=coeff_stream, gae_index_blob=index_blob,
            gae_binexp_blob=binexp_blob)

    def encode_stripe_verbatim(self, hb_start: int,
                               stripe: np.ndarray) -> ArchiveChunk:
        """Guaranteed-bound fallback for a quarantined stripe: ship the raw
        float32 values (deflate-packed).  Lossless, so the per-block l2
        error is exactly 0 <= tau for any tau; costs compression ratio on
        this stripe only.  Decoded by ``decode_stripe_verbatim``."""
        raw = np.ascontiguousarray(stripe, dtype="<f4").tobytes()
        return ArchiveChunk(
            hb_start=int(hb_start), n_hyperblocks=int(stripe.shape[0]),
            hb_stream=None, bae_streams=[], gae_coeff_stream=None,
            gae_index_blob=b"", gae_binexp_blob=b"",
            verbatim_blob=entropy.zlib_pack(raw))

    def decode_stripe_verbatim(self, chunk: ArchiveChunk) -> np.ndarray:
        """Inverse of ``encode_stripe_verbatim``; validates the payload size
        against the chunk's declared hyper-block range."""
        cfg = self.cfg
        raw = entropy.zlib_unpack(chunk.verbatim_blob)
        want = chunk.n_hyperblocks * cfg.k * cfg.block_elems * 4
        if len(raw) != want:
            raise MalformedStream(
                f"verbatim chunk holds {len(raw)} bytes for "
                f"{chunk.n_hyperblocks} hyper-blocks, expected {want}")
        return np.frombuffer(raw, "<f4").reshape(
            chunk.n_hyperblocks, cfg.k, cfg.block_elems).copy()

    def prepare_compress(self, hyperblocks: np.ndarray, tau: Optional[float],
                         mesh=None) -> int:
        """Shared compress preamble: fit the PCA basis if the caller asked
        for a guarantee and none exists yet (sharded over ``mesh`` when one
        is active).  Returns ``gae_dim``."""
        if tau is not None:
            if self.basis is None:
                self.fit_basis(hyperblocks, mesh=mesh)
            return int(self.basis.shape[0])
        return 0

    def encode_group_device(self, group, hyperblocks: np.ndarray, mesh
                            ) -> list[tuple]:
        """Device half of one shard GROUP's encode: ``len(group)`` equal-width
        stripes run as ONE ``shard_map`` call, one stripe per shard
        (``parallel.mesh_exec.plan_shard_groups`` guarantees the alignment).
        Returns per-stripe ``(q_lh, q_lbs, recon)`` tuples in span order —
        the same slices ``encode_stripe_device`` would have produced, so the
        downstream host coders cannot tell the paths apart."""
        from repro.parallel import mesh_exec
        start, stop = mesh_exec.group_slice(group)
        g_lh, g_lbs, g_recon = exec_mod.run_compress_stage_sharded(
            self.hbae_params, self._stage_params(), hyperblocks[start:stop],
            self.cfg.hb_bin, self.cfg.bae_bin, mesh)
        k = self.cfg.k
        out = []
        for s, w in group:
            lo = s - start
            out.append((g_lh[lo:lo + w],
                        [q[lo * k:(lo + w) * k] for q in g_lbs],
                        g_recon[lo:lo + w]))
        return out

    def compress(self, hyperblocks: np.ndarray, tau=_UNSET,
                 chunk_hyperblocks=_UNSET,
                 options: Optional[CompressOptions] = None) -> Archive:
        """Batch-synchronous compress: the device front-end runs stripe by
        stripe to completion, THEN the host GAE/entropy coders fan out over
        the finished stripes.  ``repro.stream.stream_compress`` runs the same
        per-stripe stages pipelined (host coding of stripe *i* overlapped
        with the device stage of stripe *i+1*) and produces byte-identical
        chunks.

        Configuration comes in as ONE ``repro.core.options.CompressOptions``
        (``options=...``); the old ``tau=``/``chunk_hyperblocks=`` kwargs
        remain as a deprecated shim.  With ``options.mesh`` set, aligned runs
        of stripes execute as single ``shard_map`` calls — one stripe per
        shard — and the archive stays byte-identical to the single-device
        result (per-shard shapes equal per-stripe shapes, so the floats are
        bit-equal, and chunk boundaries never move).
        """
        legacy = {}
        if tau is not _UNSET:
            legacy["tau"] = tau
        if chunk_hyperblocks is not _UNSET:
            legacy["chunk_hyperblocks"] = chunk_hyperblocks
        opts = resolve_options(options, legacy,
                               caller="HierarchicalCompressor.compress")
        tau = opts.tau
        n, k, d = hyperblocks.shape
        mesh = None
        if opts.mesh is not None:
            from repro.parallel import mesh_exec
            mesh = mesh_exec.resolve_mesh(opts.mesh)
        gae_dim = self.prepare_compress(hyperblocks, tau, mesh=mesh)
        spans = self.stripe_spans(n, opts.chunk_hyperblocks,
                                  with_gae=tau is not None)

        # 1+2. fused device-resident AE front-end.  Unsharded: one stripe per
        # program call (the stripe IS the archive chunk, so batch and
        # streaming run identical device shapes).  Sharded: aligned groups of
        # ``n_shards`` stripes run as one shard_map call each; the ragged
        # tail takes the per-stripe path.
        latents: list[tuple] = []
        with exec_mod.stage("ae_encode", hyperblocks.size):
            tail = spans
            if mesh is not None:
                from repro.parallel import mesh_exec
                groups, tail = mesh_exec.plan_shard_groups(
                    spans, mesh_exec.mesh_shards(mesh))
                for group in groups:
                    latents.extend(self.encode_group_device(
                        group, hyperblocks, mesh))
            for start, n_hb in tail:
                latents.append(self.encode_stripe_device(
                    hyperblocks[start:start + n_hb]))

        # 3+4. host-side GAE + entropy coding, chunk-parallel over stripes
        # (chunks are independently codable by construction).  Shard
        # boundaries coincide with stripe boundaries, so each chunk's
        # entropy fan-out consumes only rows its own shard produced.
        def encode_chunk(i: int) -> ArchiveChunk:
            start, n_hb = spans[i]
            q_lh, q_lbs, recon = latents[i]
            return self.encode_stripe_host(
                start, hyperblocks[start:start + n_hb], q_lh, q_lbs, recon,
                tau, gae_dim)

        chunks: list[Optional[ArchiveChunk]] = exec_mod.map_parallel(
            encode_chunk, range(len(spans)))

        return Archive(n_hyperblocks=n, n_values=hyperblocks.size,
                       chunk_hyperblocks=self._chunk_width(
                           opts.chunk_hyperblocks, with_gae=tau is not None),
                       gae_dim=gae_dim, chunks=chunks)

    # -- decode helpers ------------------------------------------------------
    def _decode_chunk(self, chunk: ArchiveChunk, archive: Archive
                      ) -> tuple[np.ndarray, list[np.ndarray],
                                 list[gae.GAEBlockCode]]:
        """Decode one chunk's streams into quantized latents + GAE codes,
        cross-checking every count against the model configuration.  Raises
        a typed ``ArchiveError`` on any inconsistency.  A quarantined
        (verbatim) chunk short-circuits to a ``_VerbatimStripe`` carrying the
        losslessly decoded hyper-blocks."""
        cfg = self.cfg
        if chunk.verbatim_blob:
            return _VerbatimStripe(self.decode_stripe_verbatim(chunk))
        if chunk.hb_stream is None:
            raise MalformedStream("chunk has neither latent streams nor a "
                                  "verbatim payload")
        n_hb, k, d = chunk.n_hyperblocks, cfg.k, cfg.block_elems
        want_hb = n_hb * cfg.hb_latent
        if chunk.hb_stream.count != want_hb:
            raise MalformedStream(
                f"hb stream has {chunk.hb_stream.count} symbols, "
                f"expected {want_hb}")
        q_lh = entropy.huffman_decompress(chunk.hb_stream)\
            .reshape(n_hb, cfg.hb_latent)
        if len(chunk.bae_streams) != len(self.bae_params):
            raise MalformedStream(
                f"{len(chunk.bae_streams)} BAE streams for "
                f"{len(self.bae_params)} BAE stages")
        q_lbs = []
        for stream in chunk.bae_streams:
            want = n_hb * k * cfg.bae_latent
            if stream.count != want:
                raise MalformedStream(
                    f"BAE stream has {stream.count} symbols, expected {want}")
            q_lbs.append(entropy.huffman_decompress(stream)
                         .reshape(n_hb * k, cfg.bae_latent))
        codes: list[gae.GAEBlockCode] = []
        if chunk.gae_index_blob:
            if archive.gae_dim <= 0:
                raise MalformedStream("GAE section present but gae_dim == 0")
            d_gae = cfg.gae_block_elems or d
            if (n_hb * k * d) % d_gae:
                raise MalformedStream(
                    f"chunk of {n_hb * k * d} values not divisible into "
                    f"GAE blocks of {d_gae}")
            n_gae = (n_hb * k * d) // d_gae
            index_sets = entropy.decode_index_sets(
                chunk.gae_index_blob, expect_dim=archive.gae_dim,
                expect_sets=n_gae)
            binexps = np.frombuffer(
                entropy.zlib_unpack(chunk.gae_binexp_blob), np.uint8)
            if binexps.size != n_gae:
                raise MalformedStream(
                    f"{binexps.size} bin exponents for {n_gae} GAE blocks")
            total = int(sum(s.size for s in index_sets))
            have = (chunk.gae_coeff_stream.count
                    if chunk.gae_coeff_stream is not None else 0)
            if have != total:
                raise MalformedStream(
                    f"coefficient stream has {have} values, index sets "
                    f"declare {total}")
            coeffs = (entropy.huffman_decompress(chunk.gae_coeff_stream)
                      if chunk.gae_coeff_stream is not None
                      else np.zeros(0, np.int64))
            pos = 0
            for i, idx in enumerate(index_sets):
                codes.append(gae.GAEBlockCode(
                    m=idx.size, indices=idx, qcoeffs=coeffs[pos:pos + idx.size],
                    bin_exp=int(binexps[i])))
                pos += idx.size
        return q_lh, q_lbs, codes

    def decompress(self, archive: Archive, strict: bool = True, mesh=None
                   ) -> Union[np.ndarray, tuple[np.ndarray, DamageReport]]:
        """Decode an archive back to hyper-blocks.

        ``strict=True`` (default) raises a typed ``ArchiveError`` on the first
        damaged or inconsistent chunk.  ``strict=False`` returns
        ``(reconstruction, DamageReport)``: damaged stripes decode from zeroed
        latents with no GAE correction (and no guarantee), every other stripe
        is digest-verified and still satisfies the per-block bound.

        ``mesh`` (anything ``parallel.mesh_exec.resolve_mesh`` accepts) runs
        the fused dequantize+decode back-end sharded over the hyper-block
        axis.  The sharded back-end pads the batch to an even shard split, so
        its floats can differ from the single-device decode in the last ulp —
        well inside the ``tau * (1 + 1e-5)`` slack every guarantee check in
        this repo carries.  Entropy decode and GAE correction are unchanged
        (host-side, chunk-parallel).
        """
        cfg = self.cfg
        n, k, d = archive.n_hyperblocks, cfg.k, cfg.block_elems
        report = DamageReport(n_hyperblocks=n, n_chunks=len(archive.chunks))
        if archive.gae_dim and self.basis is None:
            raise MalformedStream("archive has a GAE section but this "
                                  "compressor has no fitted basis")
        if archive.gae_dim and self.basis.shape[0] != archive.gae_dim:
            raise MalformedStream(
                f"archive GAE dimension {archive.gae_dim} != basis "
                f"dimension {self.basis.shape[0]}")
        if archive.n_values != n * k * d:
            raise MalformedStream(
                f"archive declares {archive.n_values} values for "
                f"{n}x{k}x{d} hyper-blocks")

        q_lh = np.zeros((n, cfg.hb_latent), np.int64)
        q_lbs = [np.zeros((n * k, cfg.bae_latent), np.int64)
                 for _ in self.bae_params]
        gae_codes: dict[int, gae.GAEBlockCode] = {}   # global gae-block index
        verbatim_spans: list[tuple[int, int, np.ndarray]] = []
        d_gae = cfg.gae_block_elems or d
        gae_per_hb = (k * d) // d_gae if archive.gae_dim else 0

        # Chunks are independently decodable (docs/ARCHIVE_FORMAT.md), so the
        # entropy fan-out runs on the shared pool; per-chunk errors are
        # captured and re-raised in chunk order to keep strict-mode behavior
        # deterministic and identical to the old serial loop.
        def decode_one(chunk: Optional[ArchiveChunk]):
            if chunk is None:
                return None
            try:
                return self._decode_chunk(chunk, archive)
            except ArchiveError as e:
                return e

        with exec_mod.stage("entropy_decode", archive.n_values):
            decoded = exec_mod.map_parallel(decode_one, archive.chunks)

        covered = 0
        for ci, (chunk, result) in enumerate(zip(archive.chunks, decoded)):
            if chunk is None:
                start = covered
                n_hb = min(archive.chunk_hyperblocks, n - start)
                covered += n_hb
                err = archive.chunk_errors.get(ci, "chunk unreadable")
                if strict:
                    raise MalformedStream(f"chunk {ci} damaged: {err}")
                report.damaged.append(ChunkDamage(
                    chunk=ci, hb_start=start, n_hyperblocks=n_hb,
                    section="chunk", error=err))
                continue
            if chunk.hb_start != covered:
                raise MalformedStream(
                    f"chunk {ci} starts at hyper-block {chunk.hb_start}, "
                    f"expected {covered}")
            covered += chunk.n_hyperblocks
            if isinstance(result, ArchiveError):
                if strict:
                    raise result
                report.damaged.append(ChunkDamage(
                    chunk=ci, hb_start=chunk.hb_start,
                    n_hyperblocks=chunk.n_hyperblocks, section="decode",
                    error=repr(result)))
                continue
            if isinstance(result, _VerbatimStripe):
                # quarantined stripe: raw values land after the AE backend
                # runs (its latent rows stay zero; no GAE codes exist here)
                verbatim_spans.append((chunk.hb_start,
                                       chunk.hb_start + chunk.n_hyperblocks,
                                       result.data))
                continue
            c_lh, c_lbs, c_codes = result
            s, e = chunk.hb_start, chunk.hb_start + chunk.n_hyperblocks
            q_lh[s:e] = c_lh
            for stage_i, c_lb in enumerate(c_lbs):
                q_lbs[stage_i][s * k:e * k] = c_lb
            for j, code in enumerate(c_codes):
                gae_codes[s * gae_per_hb + j] = code
        if covered != n:
            raise MalformedStream(
                f"chunks cover {covered} hyper-blocks, archive declares {n}")

        # fused dequantize+decode back-end — the same cached program that
        # produced the reconstruction the GAE encoder verified against
        # (shard_map-wrapped over the hyper-block axis when a mesh is active).
        resolved_mesh = None
        if mesh is not None:
            from repro.parallel import mesh_exec
            resolved_mesh = mesh_exec.resolve_mesh(mesh)
        with exec_mod.stage("ae_decode", archive.n_values):
            if resolved_mesh is not None:
                recon = exec_mod.run_decompress_stage_sharded(
                    self.hbae_params, self.bae_params, q_lh, q_lbs,
                    cfg.hb_bin, cfg.bae_bin, resolved_mesh)
            else:
                recon = exec_mod.run_decompress_stage(
                    self.hbae_params, self.bae_params, q_lh, q_lbs,
                    cfg.hb_bin, cfg.bae_bin)

        if archive.gae_dim and gae_codes:
            with exec_mod.stage("gae_decode", archive.n_values):
                r_gae = self._gae_view(recon)
                keys = sorted(gae_codes)
                idxs = np.fromiter(keys, np.int64, len(keys))
                sub = gae.gae_decode_blocks(r_gae[idxs], self.basis,
                                            [gae_codes[i] for i in keys],
                                            cfg.gae_bin)
                r_gae[idxs] = sub
                recon = self._gae_unview(r_gae, recon.shape)
        for s, e, data in verbatim_spans:
            recon[s:e] = data
        if strict:
            return recon
        return recon, report

    # -- persistence ---------------------------------------------------------
    # Manifest + npz layout (no pickle anywhere on the read path): a single
    # .npz holding one array per tensor plus a JSON manifest (uint8 array)
    # with per-tensor sha256 digests — the same integrity posture as
    # ``runtime.checkpoint.CheckpointManager``, whose hashing and atomic-write
    # machinery this reuses.
    def save(self, path: str) -> None:
        from repro.runtime.archive_io import atomic_write_bytes
        from repro.runtime.checkpoint import _sha

        leaves: list[tuple[str, np.ndarray]] = []
        statics: dict[str, dict] = {}
        _flatten_params({"hbae": jax.device_get(self.hbae_params),
                         "bae": jax.device_get(self.bae_params)},
                        "", leaves, statics)
        if self.basis is not None:
            leaves.append(("basis", np.asarray(self.basis)))
        manifest = {"format": MODEL_FORMAT,
                    "cfg": dataclasses.asdict(self.cfg),
                    "n_bae_stages": len(self.bae_params),
                    "has_basis": self.basis is not None,
                    "statics": statics, "tensors": []}
        arrays: dict[str, np.ndarray] = {}
        for i, (tpath, arr) in enumerate(leaves):
            arrays[f"t{i}"] = arr
            manifest["tensors"].append(
                {"key": f"t{i}", "path": tpath, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "sha256": _sha(arr)})
        arrays["__manifest__"] = np.frombuffer(
            json.dumps(manifest, sort_keys=True).encode(), np.uint8)
        import io
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        atomic_write_bytes(path, buf.getvalue())

    @classmethod
    def load(cls, path: str) -> "HierarchicalCompressor":
        from repro.runtime.checkpoint import _sha
        try:
            data = np.load(path, allow_pickle=False)
        except Exception as e:
            raise MalformedStream(f"unreadable model file {path!r}: {e}") from e
        if "__manifest__" not in data:
            raise MalformedStream(f"{path!r} has no manifest (legacy pickle "
                                  "models are not supported on the read path)")
        try:
            manifest = json.loads(bytes(data["__manifest__"]).decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise MalformedStream(f"corrupt model manifest: {e}") from e
        if manifest.get("format") != MODEL_FORMAT:
            raise MalformedStream(
                f"unsupported model format {manifest.get('format')!r}")
        entries: list[tuple[str, np.ndarray]] = []
        for t in manifest["tensors"]:
            if t["key"] not in data:
                raise MalformedStream(f"model tensor {t['path']} missing")
            arr = data[t["key"]]
            if _sha(arr) != t["sha256"]:
                raise ChecksumMismatch(f"model tensor {t['path']} hash mismatch")
            entries.append((t["path"], arr))
        tree = _assemble_params(entries, manifest.get("statics", {}))
        obj = cls(CompressorConfig(**manifest["cfg"]))
        obj.hbae_params = tree.get("hbae")
        bae = tree.get("bae", {})
        obj.bae_params = [bae[str(i)] for i in range(manifest["n_bae_stages"])]
        obj.basis = tree.get("basis") if manifest["has_basis"] else None
        return obj

    def model_bytes(self) -> int:
        """Storage cost of the decoder-side model (params + PCA basis), using
        each leaf's ACTUAL dtype width — a float16 or float64 leaf is no
        longer mis-billed at 4 bytes/element."""
        total = sum(x.size * np.dtype(x.dtype).itemsize
                    for x in jax.tree.leaves((self.hbae_params,
                                              self.bae_params)))
        if self.basis is not None:
            total += self.basis.size * np.dtype(self.basis.dtype).itemsize
        return total
