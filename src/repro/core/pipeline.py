"""End-to-end compressor pipeline (paper Fig. 1).

``HierarchicalCompressor`` ties together:
  hyper-block AE (coarse)  ->  block-wise residual AE(s) (fine)  ->
  GAE PCA post-processing (guaranteed per-block l2 bound)  ->
  quantization + Huffman + index-bitmask/zlib bitstream.

The object is fit on (a training split of) the data, then ``compress`` returns
an ``Archive`` whose ``total_bytes()`` is the honest storage cost (AE latents +
GAE coefficients + index sets + per-block headers).  Model weights and the PCA
basis are excluded by default — the paper's ratio accounting amortizes them
("we considered the latent spaces of both autoencoders, as well as the PCA
coefficients and corresponding index information", Sec. III-C); pass
``include_model_cost=True`` to count them too.
"""
from __future__ import annotations

import dataclasses
import pickle
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bae as bae_mod
from repro.core import entropy, gae
from repro.core import hbae as hbae_mod
from repro.core import training
from repro.core.quantization import dequantize, quantize

Array = jax.Array


@dataclasses.dataclass
class CompressorConfig:
    block_elems: int                 # flattened AE block size
    k: int                           # blocks per hyper-block
    emb: int = 128
    hidden: int = 256
    hb_latent: int = 128             # paper: 128 S3D / 64 E3SM,XGC
    bae_hidden: int = 256
    bae_latent: int = 16             # paper: 16 for all datasets
    heads: int = 1
    use_attention: bool = True       # False => 'HBAE-woa' ablation
    use_bae: bool = True             # False => 'HBAE' ablation
    n_bae_stages: int = 1            # 2 => 'StackAE' ablation
    hb_bin: float = 0.005
    bae_bin: float = 0.005
    gae_bin: float = 0.01
    gae_block_elems: Optional[int] = None   # GAE may re-block (paper Sec. II-D)
    epochs_hbae: int = 30
    epochs_bae: int = 30
    batch: int = 64
    lr: float = 1e-3


@dataclasses.dataclass
class Archive:
    """Compressed representation + size accounting."""
    n_hyperblocks: int
    hb_stream: entropy.HuffmanStream
    bae_streams: list[entropy.HuffmanStream]
    gae_coeff_stream: Optional[entropy.HuffmanStream]
    gae_index_blob: bytes
    gae_binexp_blob: bytes
    n_values: int                    # original float32 count

    def compressed_bytes(self) -> int:
        total = self.hb_stream.nbytes()
        total += sum(s.nbytes() for s in self.bae_streams)
        if self.gae_coeff_stream is not None:
            total += self.gae_coeff_stream.nbytes()
        total += len(self.gae_index_blob) + len(self.gae_binexp_blob)
        return total + 32  # fixed header

    def compression_ratio(self, include_model_bytes: int = 0) -> float:
        return (self.n_values * 4) / (self.compressed_bytes() + include_model_bytes)


class HierarchicalCompressor:
    """fit / compress / decompress on hyper-block-shaped data (N, k, D)."""

    def __init__(self, config: CompressorConfig):
        self.cfg = config
        self.hbae_params: Optional[dict] = None
        self.bae_params: list[dict] = []
        self.basis: Optional[np.ndarray] = None

    # -- training ----------------------------------------------------------
    def fit(self, hyperblocks: np.ndarray, seed: int = 0,
            log: Optional[Callable] = None) -> "HierarchicalCompressor":
        cfg = self.cfg
        n, k, d = hyperblocks.shape
        assert k == cfg.k and d == cfg.block_elems, (hyperblocks.shape, cfg)
        key = jax.random.PRNGKey(seed)
        khb, *kbs = jax.random.split(key, 1 + max(cfg.n_bae_stages, 1))
        self.hbae_params = training.train_hbae(
            khb, hyperblocks, emb=cfg.emb, hidden=cfg.hidden, latent=cfg.hb_latent,
            heads=cfg.heads, use_attention=cfg.use_attention,
            epochs=cfg.epochs_hbae, batch=cfg.batch, lr=cfg.lr, seed=seed, log=log)
        if cfg.use_bae:
            y, _ = self._hbae_forward(hyperblocks)
            resid = (hyperblocks - y).reshape(n * k, d)
            self.bae_params = []
            for s in range(cfg.n_bae_stages):
                p = training.train_bae(kbs[s], resid, hidden=cfg.bae_hidden,
                                       latent=cfg.bae_latent, epochs=cfg.epochs_bae,
                                       batch=max(cfg.batch * 4, 256), lr=cfg.lr,
                                       seed=seed + s, log=log)
                self.bae_params.append(p)
                r_hat, _ = jax.jit(bae_mod.bae_apply)(p, jnp.asarray(resid))
                resid = resid - np.asarray(r_hat)
        return self

    # -- forward helpers ----------------------------------------------------
    def _hbae_forward(self, hyperblocks: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        y, latent = jax.jit(hbae_mod.hbae_apply)(self.hbae_params, jnp.asarray(hyperblocks))
        return np.asarray(y), np.asarray(latent)

    def reconstruct_ae(self, hyperblocks: np.ndarray,
                       quantize_latents: bool = True) -> np.ndarray:
        """AE-only reconstruction (through quantized latents when requested)."""
        cfg = self.cfg
        n, k, d = hyperblocks.shape
        latent = np.asarray(jax.jit(hbae_mod.hbae_encode)(self.hbae_params,
                                                          jnp.asarray(hyperblocks)))
        if quantize_latents:
            latent = np.asarray(dequantize(quantize(jnp.asarray(latent), cfg.hb_bin),
                                           cfg.hb_bin))
        y = np.asarray(jax.jit(hbae_mod.hbae_decode)(self.hbae_params, jnp.asarray(latent)))
        recon = y
        if cfg.use_bae:
            resid = (hyperblocks - y).reshape(n * k, d)
            for p in self.bae_params:
                lb = np.asarray(jax.jit(bae_mod.bae_encode)(p, jnp.asarray(resid)))
                if quantize_latents:
                    lb = np.asarray(dequantize(quantize(jnp.asarray(lb), cfg.bae_bin),
                                               cfg.bae_bin))
                r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, jnp.asarray(lb)))
                recon = recon + r_hat.reshape(n, k, d)
                resid = resid - r_hat
        return recon

    # -- PCA basis -----------------------------------------------------------
    def fit_basis(self, hyperblocks: np.ndarray) -> np.ndarray:
        """PCA basis of AE residuals at GAE block granularity."""
        recon = self.reconstruct_ae(hyperblocks)
        resid = self._gae_view(hyperblocks - recon)
        self.basis = np.asarray(gae.fit_pca_basis(jnp.asarray(resid)))
        return self.basis

    def _gae_view(self, blocks3d: np.ndarray) -> np.ndarray:
        """(N, k, D) -> (N_gae, D_gae): GAE may use a different block size."""
        d_gae = self.cfg.gae_block_elems or self.cfg.block_elems
        flat = blocks3d.reshape(-1)
        assert flat.size % d_gae == 0
        return flat.reshape(-1, d_gae)

    def _gae_unview(self, gae_blocks: np.ndarray, shape3d: tuple) -> np.ndarray:
        return gae_blocks.reshape(shape3d)

    # -- compress / decompress ----------------------------------------------
    def compress(self, hyperblocks: np.ndarray, tau: Optional[float] = None) -> Archive:
        cfg = self.cfg
        n, k, d = hyperblocks.shape

        # 1. hyper-block AE latents (quantized ints -> Huffman)
        latent = np.asarray(jax.jit(hbae_mod.hbae_encode)(self.hbae_params,
                                                          jnp.asarray(hyperblocks)))
        q_lh = np.asarray(quantize(jnp.asarray(latent), cfg.hb_bin))
        hb_stream = entropy.huffman_compress(q_lh)
        lat_deq = np.asarray(dequantize(jnp.asarray(q_lh), cfg.hb_bin))
        y = np.asarray(jax.jit(hbae_mod.hbae_decode)(self.hbae_params,
                                                     jnp.asarray(lat_deq)))

        # 2. block-wise residual AE stage(s)
        recon = y
        bae_streams = []
        if cfg.use_bae:
            resid = (hyperblocks - recon).reshape(n * k, d)
            for p in self.bae_params:
                lb = np.asarray(jax.jit(bae_mod.bae_encode)(p, jnp.asarray(resid)))
                q_lb = np.asarray(quantize(jnp.asarray(lb), cfg.bae_bin))
                bae_streams.append(entropy.huffman_compress(q_lb))
                lb_deq = np.asarray(dequantize(jnp.asarray(q_lb), cfg.bae_bin))
                r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, jnp.asarray(lb_deq)))
                recon = recon + r_hat.reshape(n, k, d)
                resid = resid - r_hat

        # 3. GAE error-bound post-processing
        gae_coeff_stream = None
        index_blob = b""
        binexp_blob = b""
        if tau is not None:
            if self.basis is None:
                self.fit_basis(hyperblocks)
            x_gae = self._gae_view(hyperblocks)
            r_gae = self._gae_view(recon)
            _, codes = gae.gae_encode_blocks(x_gae, r_gae, self.basis, tau, cfg.gae_bin)
            # store coefficients in ascending-index order (bitmask decode order)
            all_coeffs, index_sets, binexps = [], [], []
            for c in codes:
                asc = np.argsort(c.indices)
                index_sets.append(np.sort(c.indices))
                all_coeffs.append(c.qcoeffs[asc])
                binexps.append(c.bin_exp)
            coeffs = (np.concatenate(all_coeffs) if all_coeffs else
                      np.zeros(0, np.int64))
            if coeffs.size:
                gae_coeff_stream = entropy.huffman_compress(coeffs)
            dim = self.basis.shape[0]
            index_blob = entropy.encode_index_sets(index_sets, dim)
            binexp_blob = entropy.zlib_pack(np.asarray(binexps, np.uint8).tobytes())

        return Archive(n_hyperblocks=n, hb_stream=hb_stream, bae_streams=bae_streams,
                       gae_coeff_stream=gae_coeff_stream, gae_index_blob=index_blob,
                       gae_binexp_blob=binexp_blob, n_values=hyperblocks.size)

    def decompress(self, archive: Archive) -> np.ndarray:
        cfg = self.cfg
        n, k, d = archive.n_hyperblocks, cfg.k, cfg.block_elems
        q_lh = entropy.huffman_decompress(archive.hb_stream).reshape(n, cfg.hb_latent)
        lat = np.asarray(dequantize(jnp.asarray(q_lh), cfg.hb_bin))
        y = np.asarray(jax.jit(hbae_mod.hbae_decode)(self.hbae_params, jnp.asarray(lat)))
        recon = y
        for p, stream in zip(self.bae_params, archive.bae_streams):
            q_lb = entropy.huffman_decompress(stream).reshape(n * k, cfg.bae_latent)
            lb = np.asarray(dequantize(jnp.asarray(q_lb), cfg.bae_bin))
            r_hat = np.asarray(jax.jit(bae_mod.bae_decode)(p, jnp.asarray(lb)))
            recon = recon + r_hat.reshape(n, k, d)

        if archive.gae_index_blob:
            index_sets = entropy.decode_index_sets(archive.gae_index_blob)
            binexps = np.frombuffer(entropy.zlib_unpack(archive.gae_binexp_blob),
                                    np.uint8)
            coeffs = (entropy.huffman_decompress(archive.gae_coeff_stream)
                      if archive.gae_coeff_stream is not None else np.zeros(0, np.int64))
            r_gae = self._gae_view(recon)
            pos = 0
            codes = []
            for i, idx in enumerate(index_sets):
                m = idx.size
                codes.append(gae.GAEBlockCode(m=m, indices=idx,
                                              qcoeffs=coeffs[pos:pos + m],
                                              bin_exp=int(binexps[i])))
                pos += m
            out = gae.gae_decode_blocks(r_gae, self.basis, codes, cfg.gae_bin)
            recon = self._gae_unview(out, recon.shape)
        return recon

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        state = {"cfg": self.cfg,
                 "hbae": jax.device_get(self.hbae_params),
                 "bae": jax.device_get(self.bae_params),
                 "basis": self.basis}
        with open(path, "wb") as f:
            pickle.dump(state, f)

    @classmethod
    def load(cls, path: str) -> "HierarchicalCompressor":
        with open(path, "rb") as f:
            state = pickle.load(f)
        obj = cls(state["cfg"])
        obj.hbae_params = state["hbae"]
        obj.bae_params = state["bae"]
        obj.basis = state["basis"]
        return obj

    def model_bytes(self) -> int:
        total = sum(x.size * 4 for x in jax.tree.leaves((self.hbae_params,
                                                         self.bae_params)))
        if self.basis is not None:
            total += self.basis.size * 4
        return total
