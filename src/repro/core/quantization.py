"""Uniform quantization (paper Sec. II-E).

Values are binned into uniform bins of width ``bin_size``; every value in a bin
is represented by the bin's central value.  ``quantize`` returns integer bin
indices (storable / entropy-codable), ``dequantize`` maps back to centers.

Traceable under jit; also used inside Pallas kernels via the same formulas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize(x: Array, bin_size: float | Array) -> Array:
    """float -> int32 bin index (round-to-nearest => bin centers)."""
    return jnp.round(x / bin_size).astype(jnp.int32)


def dequantize(q: Array, bin_size: float | Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * bin_size).astype(dtype)


def quantize_dequantize(x: Array, bin_size: float | Array) -> Array:
    """Fused round-trip: the value the decoder will see."""
    return dequantize(quantize(x, bin_size), bin_size, dtype=x.dtype)


def quantization_error_bound(bin_size: float, n: int) -> float:
    """Worst-case l2 error of uniformly quantizing an n-vector: sqrt(n)*bin/2."""
    return float(bin_size) * 0.5 * float(n) ** 0.5
