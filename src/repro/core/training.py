"""Training loops for the compressor (paper Sec. III-C).

The HBAE is trained first, then the BAE on the HBAE residuals (stacked BAE
stages for the StackAE ablation).  MSE loss, Adam lr=1e-3 as in the paper.
Data-parallel training over hyper-blocks is expressed with
``jax.jit(in_shardings=...)`` in ``repro.launch.train``; the loops here are
mesh-agnostic (they jit plain update steps and stream minibatches).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bae as bae_mod
from repro.core import hbae as hbae_mod
from repro.train import optim as optim_mod

Array = jax.Array


def _minibatches(rng: np.random.Generator, n: int, batch: int, epochs: int):
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            yield order[i:i + batch]


# ---------------------------------------------------------------------------
# HBAE
# ---------------------------------------------------------------------------

def hbae_loss(params: dict, x: Array) -> Array:
    y, _ = hbae_mod.hbae_apply(params, x)
    return jnp.mean(jnp.square(y - x))


@functools.partial(jax.jit, static_argnames=("opt",), donate_argnums=(0, 1))
def _hbae_step(params, opt_state, x, opt):
    loss, grads = jax.value_and_grad(hbae_loss)(params, x)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss


def train_hbae(key: Array, hyperblocks: np.ndarray, *, emb: int = 128,
               hidden: int = 256, latent: int = 128, heads: int = 1,
               use_attention: bool = True, epochs: int = 30, batch: int = 64,
               lr: float = 1e-3, seed: int = 0,
               log: Optional[Callable[[int, float], None]] = None) -> dict:
    n, k, d = hyperblocks.shape
    params = hbae_mod.hbae_init(key, in_dim=d, k=k, emb=emb, hidden=hidden,
                                latent=latent, heads=heads,
                                use_attention=use_attention)
    opt = optim_mod.adam(lr=lr)
    opt_state = opt.init(params)
    rng = np.random.default_rng(seed)
    batch = min(batch, n)
    data = jnp.asarray(hyperblocks)
    for step, idx in enumerate(_minibatches(rng, n, batch, epochs)):
        params, opt_state, loss = _hbae_step(params, opt_state, data[idx], opt)
        if log is not None and step % 50 == 0:
            log(step, float(loss))
    return params


# ---------------------------------------------------------------------------
# BAE
# ---------------------------------------------------------------------------

def bae_loss(params: dict, residual: Array) -> Array:
    r_hat, _ = bae_mod.bae_apply(params, residual)
    return jnp.mean(jnp.square(r_hat - residual))


@functools.partial(jax.jit, static_argnames=("opt",), donate_argnums=(0, 1))
def _bae_step(params, opt_state, r, opt):
    loss, grads = jax.value_and_grad(bae_loss)(params, r)
    params, opt_state, _ = opt.update(grads, opt_state, params)
    return params, opt_state, loss


def train_bae(key: Array, residuals: np.ndarray, *, hidden: int = 256,
              latent: int = 16, epochs: int = 30, batch: int = 256,
              lr: float = 1e-3, seed: int = 0,
              log: Optional[Callable[[int, float], None]] = None) -> dict:
    n, d = residuals.shape
    params = bae_mod.bae_init(key, in_dim=d, hidden=hidden, latent=latent)
    opt = optim_mod.adam(lr=lr)
    opt_state = opt.init(params)
    rng = np.random.default_rng(seed)
    batch = min(batch, n)
    data = jnp.asarray(residuals)
    for step, idx in enumerate(_minibatches(rng, n, batch, epochs)):
        params, opt_state, loss = _bae_step(params, opt_state, data[idx], opt)
        if log is not None and step % 100 == 0:
            log(step, float(loss))
    return params
