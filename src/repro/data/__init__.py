from repro.data import blocks, synthetic  # noqa: F401
