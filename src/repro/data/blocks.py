"""Blocking / hyper-block grouping / normalization for gridded scientific data.

Mirrors the paper's Sec. III data preparation:
  * S3D  : 4D (species 58, T 50, H 640, W 640) -> blocks (58,5,4,4); 10
           consecutive temporal blocks form one hyper-block; per-species
           normalization to mean 0 / range 1; GAE at (5,4,4) per species.
  * E3SM : (T 720, H 240, W 1440) -> blocks (6,16,16); 5 consecutive temporal
           blocks per hyper-block; z-score normalization; GAE at (16,16).
  * XGC  : (planes 8, nodes, 39, 39) -> each (39,39) histogram is a block; the
           8 planes at one node form a hyper-block; z-score; GAE per histogram.

``block_nd``/``unblock_nd`` are exact inverses for any divisible shape.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class BlockMeta:
    data_shape: tuple[int, ...]
    block_shape: tuple[int, ...]
    grid_shape: tuple[int, ...]


def block_nd(data: np.ndarray, block_shape: Sequence[int]) -> tuple[np.ndarray, BlockMeta]:
    """(d1..dn) -> (n_blocks, prod(block_shape)), row-major over the block grid."""
    shape = data.shape
    bs = tuple(block_shape)
    assert len(bs) == data.ndim, (shape, bs)
    assert all(s % b == 0 for s, b in zip(shape, bs)), f"{shape} not divisible by {bs}"
    grid = tuple(s // b for s, b in zip(shape, bs))
    # interleave grid and block axes, then bring grid axes first
    inter = []
    for g, b in zip(grid, bs):
        inter.extend([g, b])
    x = data.reshape(inter)
    n = data.ndim
    x = x.transpose(*range(0, 2 * n, 2), *range(1, 2 * n, 2))
    blocks = x.reshape(int(np.prod(grid)), int(np.prod(bs)))
    return np.ascontiguousarray(blocks), BlockMeta(tuple(shape), bs, grid)


def unblock_nd(blocks: np.ndarray, meta: BlockMeta) -> np.ndarray:
    grid, bs = meta.grid_shape, meta.block_shape
    n = len(bs)
    x = blocks.reshape(*grid, *bs)
    perm = []
    for i in range(n):
        perm.extend([i, n + i])
    x = x.transpose(*perm)
    return np.ascontiguousarray(x.reshape(meta.data_shape))


def group_hyperblocks(blocks: np.ndarray, k: int) -> np.ndarray:
    """(N, D) -> (N//k, k, D): k consecutive blocks per hyper-block (the paper
    groups along the leading/temporal grid axis; block_nd's row-major grid
    ordering makes consecutive blocks temporal neighbours when the temporal
    axis is the fastest-varying grid axis — callers arrange axes accordingly)."""
    n, d = blocks.shape
    assert n % k == 0, (n, k)
    return blocks.reshape(n // k, k, d)


def ungroup_hyperblocks(hblocks: np.ndarray) -> np.ndarray:
    nh, k, d = hblocks.shape
    return hblocks.reshape(nh * k, d)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Normalizer:
    """Invertible affine normalization with stored statistics.

    mode='range'  : per-channel mean 0, range 1 (paper's S3D per-species setup)
    mode='zscore' : global z-score (paper's E3SM / XGC setup)
    """
    mode: str
    offset: np.ndarray
    scale: np.ndarray
    axis: int | None

    @staticmethod
    def fit(data: np.ndarray, mode: str = "zscore", axis: int | None = None) -> "Normalizer":
        if mode == "zscore":
            off = np.asarray(data.mean(), np.float32)
            sc = np.asarray(max(float(data.std()), 1e-12), np.float32)
            return Normalizer("zscore", off, sc, None)
        if mode == "range":
            assert axis is not None
            red = tuple(i for i in range(data.ndim) if i != axis)
            mean = data.mean(axis=red, keepdims=True).astype(np.float32)
            rng = (data.max(axis=red, keepdims=True) - data.min(axis=red, keepdims=True))
            rng = np.maximum(rng, 1e-12).astype(np.float32)
            return Normalizer("range", mean, rng, axis)
        raise ValueError(mode)

    def forward(self, data: np.ndarray) -> np.ndarray:
        return ((data - self.offset) / self.scale).astype(np.float32)

    def inverse(self, data: np.ndarray) -> np.ndarray:
        return (data * self.scale + self.offset).astype(np.float32)


def nrmse(original: np.ndarray, recon: np.ndarray) -> float:
    """Paper Eq. 11."""
    rng = float(original.max() - original.min())
    rng = max(rng, 1e-30)
    return float(np.sqrt(np.mean(np.square(original - recon))) / rng)
