"""Synthetic surrogates for the paper's three datasets (S3D / E3SM / XGC).

The real datasets are not redistributable offline.  These generators match the
*structure* the paper's method exploits — strong spatiotemporal correlation,
strong inter-variable (species / plane) correlation, block-structured meshes —
at configurable sizes so tests run in seconds and benchmarks in minutes.
Absolute compression ratios therefore differ from the paper; relative orderings
are what EXPERIMENTS.md validates (see DESIGN.md §1).

All generators are deterministic in ``seed`` and return float32.

``make_dataset(name, quick=...)`` is the shared entry point (launchers,
benchmarks, examples): it generates the field, applies the paper's
normalization, blocks it at the paper's geometry and groups hyper-blocks,
returning (CompressorConfig, hyperblocks).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _fourier_field(rng: np.random.Generator, t: int, h: int, w: int,
                   n_modes: int = 12, t_speed: float = 0.35,
                   warp: float = 0.6) -> np.ndarray:
    """Smooth multiscale advecting field (T,H,W): sum of random Fourier modes
    with 1/k amplitude decay and temporal phase advection.

    ``warp`` adds a nonlinear time-warp per mode (accelerating/decelerating
    advection, as in real ignition fronts): phase(t) = omega*(t + a*T*
    sin(2*pi*t/T + phi)).  Inter-block temporal relationships then VARY by
    position in the sequence — the structure content-based attention can
    exploit but a fixed linear cross-block mix cannot."""
    ys, xs = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    field = np.zeros((t, h, w), np.float32)
    ts = np.arange(t, dtype=np.float64)[:, None, None]
    for _ in range(n_modes):
        kx = rng.integers(1, max(2, w // 8))
        ky = rng.integers(1, max(2, h // 8))
        amp = 1.0 / np.hypot(kx, ky)
        phase = rng.uniform(0, 2 * np.pi)
        omega = t_speed * rng.uniform(-1, 1)
        aw = warp * rng.uniform(0, 1)
        tw = ts + aw * t / (2 * np.pi) * np.sin(2 * np.pi * ts / t +
                                                rng.uniform(0, 2 * np.pi))
        arg = (2 * np.pi * (kx * xs / w + ky * ys / h))[None] + omega * tw + phase
        field += (amp * np.cos(arg)).astype(np.float32)
    return field


def s3d_like(n_species: int = 58, t: int = 50, h: int = 640, w: int = 640,
             rank: int = 8, noise: float = 1e-3, seed: int = 0) -> np.ndarray:
    """(species, T, H, W): species are nonlinear mixtures of ``rank`` latent
    fields, reproducing the strong inter-species correlation of S3D ([13] in
    the paper) that the hyper-block attention is designed to exploit."""
    rng = np.random.default_rng(seed)
    latents = np.stack([_fourier_field(rng, t, h, w) for _ in range(rank)])  # (r,T,H,W)
    mix = rng.normal(size=(n_species, rank)).astype(np.float32)
    mix /= np.linalg.norm(mix, axis=1, keepdims=True)
    base = np.tensordot(mix, latents, axes=(1, 0))                           # (S,T,H,W)
    # per-species monotone nonlinearity (species concentrations are positive,
    # exponentially distributed in magnitude like ignition chemistry)
    gains = rng.uniform(0.5, 2.0, size=n_species).astype(np.float32)
    scales = np.exp(rng.uniform(-3, 3, size=n_species)).astype(np.float32)
    out = np.empty_like(base)
    for s in range(n_species):
        out[s] = scales[s] * np.exp(gains[s] * np.tanh(base[s]))
    out += noise * rng.standard_normal(out.shape).astype(np.float32) * out.std()
    return out.astype(np.float32)


# ---------------------------------------------------------------------------
# dataset assembly at the paper's block/hyper-block geometry
# ---------------------------------------------------------------------------

# (full-size kwargs, quick kwargs) per dataset
_SIZES = {
    # quick sizes keep a full-length temporal axis (hyper-blocks group k
    # CONSECUTIVE TEMPORAL blocks per the paper, so t_grid must be >= k)
    "s3d": (dict(n_species=58, t=50, h=640, w=640),
            dict(n_species=58, t=50, h=48, w=48)),      # t_grid=10=k
    "e3sm": (dict(t=720, h=240, w=1440), dict(t=60, h=48, w=96)),  # t_grid=10
    "xgc": (dict(planes=8, nodes=16395, v=39),
            dict(planes=8, nodes=1024, v=39)),
}


def _temporal_major(blocks: np.ndarray, grid: tuple, t_axis: int) -> np.ndarray:
    """Reorder a row-major block grid so the TEMPORAL grid axis varies fastest
    — the paper groups k consecutive temporal blocks (same spatial location)
    into one hyper-block (Sec. III: 'Continuous, non-overlapping blocks ...
    along the temporal dimension')."""
    order = [i for i in range(len(grid)) if i != t_axis] + [t_axis]
    b = blocks.reshape(*grid, blocks.shape[1])
    b = np.transpose(b, order + [len(grid)])
    return np.ascontiguousarray(b.reshape(-1, blocks.shape[1]))


def make_dataset(name: str, *, quick: bool = True, seed: int = 0,
                 epochs_scale: float | None = None):
    """Generate + normalize + block a synthetic dataset at the paper's
    geometry.  Returns (CompressorConfig, hyperblocks (N, k, D) float32).

    ``quick`` shrinks the field (same block geometry) and the train epochs so
    tests/benchmarks run in minutes; S3D keeps all 58 species — the
    inter-species correlation is what the method exploits.
    """
    import dataclasses as _dc

    from repro.configs import get_compressor_config
    from repro.data import blocks as blocks_mod

    cfg = get_compressor_config(name)
    full, small = _SIZES[name]
    kwargs = small if quick else full

    if name == "s3d":
        data = s3d_like(seed=seed, **kwargs)
        norm = blocks_mod.Normalizer.fit(data, mode="range", axis=0)
        data = norm.forward(data)
        blocks, meta = blocks_mod.block_nd(data, (data.shape[0], 5, 4, 4))
        # hyper-blocks = 10 consecutive TEMPORAL blocks (grid axis 1)
        blocks = _temporal_major(blocks, meta.grid_shape, t_axis=1)
    elif name == "e3sm":
        data = e3sm_like(seed=seed, **kwargs)
        norm = blocks_mod.Normalizer.fit(data, mode="zscore")
        data = norm.forward(data)
        blocks, meta = blocks_mod.block_nd(data, (6, 16, 16))
        # hyper-blocks = 5 consecutive TEMPORAL blocks (grid axis 0)
        blocks = _temporal_major(blocks, meta.grid_shape, t_axis=0)
    else:  # xgc
        data = xgc_like(seed=seed, **kwargs)
        norm = blocks_mod.Normalizer.fit(data, mode="zscore")
        data = norm.forward(data)
        # hyper-block = the 8 planes at one node: reorder to (nodes, planes)
        p, n, v, _ = data.shape
        blocks = data.transpose(1, 0, 2, 3).reshape(n * p, v * v)
    hb = blocks_mod.group_hyperblocks(blocks, cfg.k)
    if quick:
        cfg = _dc.replace(cfg, epochs_hbae=30, epochs_bae=20, hidden=256,
                          bae_hidden=256)
    if epochs_scale:
        cfg = _dc.replace(cfg, epochs_hbae=max(1, int(cfg.epochs_hbae * epochs_scale)),
                          epochs_bae=max(1, int(cfg.epochs_bae * epochs_scale)))
    return cfg, hb.astype(np.float32)


def e3sm_like(t: int = 720, h: int = 240, w: int = 1440, seed: int = 0,
              noise: float = 5e-4) -> np.ndarray:
    """(T,H,W) sea-level-pressure-like field: zonal banding + advecting eddies
    + a diurnal cycle (period 24 steps), matching the E3SM PSL structure."""
    rng = np.random.default_rng(seed)
    lat = np.linspace(-np.pi / 2, np.pi / 2, h)[None, :, None]
    zonal = 1013.0 + 8.0 * np.cos(2 * lat) - 3.0 * np.cos(4 * lat)
    eddies = 6.0 * _fourier_field(rng, t, h, w, n_modes=20, t_speed=0.2)
    diurnal = 1.5 * np.sin(2 * np.pi * np.arange(t) / 24.0)[:, None, None]
    out = zonal + eddies + diurnal
    out += noise * rng.standard_normal(out.shape) * out.std()
    return out.astype(np.float32)


def xgc_like(planes: int = 8, nodes: int = 16395, v: int = 39, seed: int = 0,
             plane_jitter: float = 0.02, noise: float = 1e-3) -> np.ndarray:
    """(planes, nodes, v, v) velocity-space histograms: per-node drifting
    anisotropic Maxwellians; the 8 toroidal planes are near-copies (the strong
    cross-plane correlation the paper groups into hyper-blocks)."""
    rng = np.random.default_rng(seed)
    vpar, vperp = np.meshgrid(np.linspace(-3, 3, v), np.linspace(-3, 3, v),
                              indexing="ij")
    # smooth node profiles (nodes ordered along a flux surface -> 1D smooth)
    def smooth_profile(lo, hi):
        raw = rng.standard_normal(nodes)
        kernel = np.exp(-0.5 * (np.arange(-50, 51) / 15.0) ** 2)
        kernel /= kernel.sum()
        sm = np.convolve(raw, kernel, mode="same")
        sm = (sm - sm.min()) / max(float(np.ptp(sm)), 1e-9)
        return (lo + (hi - lo) * sm).astype(np.float32)

    temp_par = smooth_profile(0.6, 1.6)[:, None, None]
    temp_perp = smooth_profile(0.6, 1.6)[:, None, None]
    drift = smooth_profile(-0.8, 0.8)[:, None, None]
    dens = smooth_profile(0.5, 2.0)[:, None, None]
    base = dens * np.exp(-((vpar[None] - drift) ** 2) / (2 * temp_par)
                         - (vperp[None] ** 2) / (2 * temp_perp))
    out = np.empty((planes, nodes, v, v), np.float32)
    for p in range(planes):
        pert = 1.0 + plane_jitter * rng.standard_normal((nodes, 1, 1)).astype(np.float32)
        shift = plane_jitter * rng.standard_normal()
        out[p] = base * pert * (1.0 + shift)
    out += noise * rng.standard_normal(out.shape).astype(np.float32) * out.std()
    return out.astype(np.float32)
