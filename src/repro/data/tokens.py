"""Deterministic, shardable, resumable-by-step LM token pipeline.

Requirements from the fault-tolerance story (DESIGN.md §7): after a crash the
runner restores step N and must see the EXACT batch stream it would have seen
without the crash — so batches are a pure function of (step, shard).  No
iterator state is ever checkpointed; ``batch_at(step)`` is the contract.

The corpus here is a synthetic-but-structured Zipfian n-gram stream (offline
container: no real corpora); real deployments swap ``SyntheticCorpus`` for a
tokenized shard reader behind the same ``batch_at`` interface.  Host prefetch
(depth >= 2) decouples host hiccups from the device stream.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    shard_index: int = 0          # this host's data shard
    shard_count: int = 1
    seed: int = 1234


class SyntheticCorpus:
    """Zipfian unigram mixture with a deterministic per-position bigram kick —
    enough structure that a ~100M model's loss visibly drops, fully
    reproducible from (seed, step, shard)."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._probs = p / p.sum()
        # deterministic bigram successor table: v -> (a*v + b) % vocab
        rng = np.random.default_rng(cfg.seed)
        self._a = int(rng.integers(1, cfg.vocab - 1) | 1)
        self._b = int(rng.integers(0, cfg.vocab))

    def batch_at(self, step: int) -> dict:
        """Pure function of (step, shard): {'tokens','labels'} int32 arrays of
        shape (local_batch, seq_len)."""
        cfg = self.cfg
        assert cfg.global_batch % cfg.shard_count == 0
        local = cfg.global_batch // cfg.shard_count
        ss = np.random.SeedSequence(
            entropy=cfg.seed, spawn_key=(step, cfg.shard_index))
        rng = np.random.default_rng(ss)
        base = rng.choice(cfg.vocab, size=(local, cfg.seq_len + 1),
                          p=self._probs).astype(np.int64)
        # bigram kick: with p=0.5 the next token is the deterministic successor
        follow = rng.random((local, cfg.seq_len)) < 0.5
        succ = (self._a * base[:, :-1] + self._b) % cfg.vocab
        seq = base.copy()
        seq[:, 1:] = np.where(follow, succ, base[:, 1:])
        return {"tokens": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}


class PrefetchIterator:
    """Threaded prefetch (depth >= 2) over ``batch_at`` starting at ``step``.

    A worker-thread crash (corrupt shard, OOM in ``batch_at``) is re-raised
    from ``__next__`` on the consumer thread — an error sentinel rides the
    queue, so the consumer never blocks forever on a dead producer.
    ``close()`` joins the worker.
    """

    _ERR = object()      # queue sentinel: payload is the worker's exception

    def __init__(self, corpus: SyntheticCorpus, start_step: int,
                 depth: int = 2):
        self.corpus = corpus
        self.step = start_step
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        s = self.step
        try:
            while not self._stop.is_set():
                batch = self.corpus.batch_at(s)
                while not self._stop.is_set():
                    try:
                        self.q.put((s, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1
        except BaseException as e:   # propagate to consumer via the queue
            self._exc = e
            while not self._stop.is_set():
                try:
                    self.q.put((self._ERR, e), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._exc is not None and self.q.empty():
            raise self._exc
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                tag, batch = self.q.get(timeout=0.5)
            except queue.Empty:
                # producer dead without a queued sentinel (e.g. it crashed
                # while the queue was full and close() drained it)?
                if not self._thread.is_alive():
                    if self._exc is not None:
                        raise self._exc
                    raise StopIteration
                continue
            if tag is self._ERR:
                raise batch
            return batch

    def close(self) -> None:
        """Stop and JOIN the worker; safe to call twice."""
        self._stop.set()
        while True:     # unblock a producer stuck on a full queue
            try:
                self.q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)


def make_iterator(cfg: TokenPipelineConfig, start_step: int,
                  prefetch: int = 2) -> PrefetchIterator:
    return PrefetchIterator(SyntheticCorpus(cfg), start_step, depth=prefetch)
