"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §4).

Each kernel is a package ``<name>/{kernel.py, ops.py, ref.py}``: the Pallas
``pallas_call`` + BlockSpec tiling, the jit'd public wrapper (interpret-mode
fallback off-TPU), and the pure-jnp oracle the tests sweep against.

  flash_attention  — online-softmax causal GQA + sliding window (LM layers)
  block_attention  — fused tiny-n hyper-block attention (HBAE, paper Eq. 6)
  gae_project      — fused U^T r + c^2 (GAE, paper Eq. 9 / Algorithm 1 input)
  quantize         — fused bin / dequant / sq-error (paper Sec. II-E)
  ssd_scan         — Mamba-2 chunked SSD scan, state carried in VMEM
"""
