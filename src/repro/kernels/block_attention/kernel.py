"""Fused hyper-block attention Pallas kernel (HBAE, paper Eq. 6 core).

TPU adaptation (DESIGN.md §4): the HBAE attends over only k <= 16 block
embeddings of d = 128 per hyper-block — a *tiny-n, batch-huge* attention.
FlashAttention-style KV streaming is pointless at n = 10; the win is batching
``tb`` whole hyper-blocks into one VMEM tile of shape (tb, n, d) and fusing
QK^T -> softmax -> PV for the whole tile so the intermediates (tb, n, n) never
round-trip to HBM.  Softmax numerics are fp32 on-chip; I/O keeps the input
dtype.  The grid is 1-D over hyper-block tiles — every cell independent
("parallel" semantics).

VMEM budget: 4 tensors x tb*n*d*4 B + scores tb*n*n*4 B; at tb=256, n=10,
d=128 that's ~5.6 MB « 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _block_attn_kernel(q_ref, k_ref, v_ref, o_ref, *, heads: int):
    q = q_ref[...].astype(jnp.float32)            # (tb, n, d)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    tb, n, dk = q.shape
    dv = v.shape[-1]
    hq = q.reshape(tb, n, heads, dk // heads)
    hk = k.reshape(tb, n, heads, dk // heads)
    hv = v.reshape(tb, n, heads, dv // heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", hq, hk,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dk // heads, jnp.float32))
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", w, hv,
                     preferred_element_type=jnp.float32)
    o_ref[...] = ctx.reshape(tb, n, dv).astype(o_ref.dtype)


def block_attention_fwd(q: Array, k: Array, v: Array, *, heads: int = 1,
                        tile_b: int = 256, interpret: bool = False) -> Array:
    """q/k/v: (B, n, d) with B a multiple of tile_b (wrapper pads)."""
    b, n, dk = q.shape
    dv = v.shape[-1]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, (b, tile_b)
    grid = (b // tile_b,)
    kernel = functools.partial(_block_attn_kernel, heads=heads)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_b, n, dk), lambda i: (i, 0, 0)),
                  pl.BlockSpec((tile_b, n, dk), lambda i: (i, 0, 0)),
                  pl.BlockSpec((tile_b, n, dv), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, n, dv), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, dv), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, k, v)
