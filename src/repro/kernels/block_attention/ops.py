"""Jit'd public wrapper around the hyper-block attention kernel.

Handles arbitrary leading batch shape, pads the hyper-block batch to the tile
size (padded rows compute garbage that is sliced away — softmax over real
columns only, since padding is along batch, never along n), and interprets
off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_attention.kernel import block_attention_fwd

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("heads", "tile_b", "interpret"))
def block_attention(q: Array, k: Array, v: Array, *, heads: int = 1,
                    tile_b: int = 256, interpret: bool | None = None) -> Array:
    """q/k/v: (..., n, d) -> (..., n, d_v)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    *lead, n, dk = q.shape
    dv = v.shape[-1]
    b = 1
    for x in lead:
        b *= x
    qf = q.reshape(b, n, dk)
    kf = k.reshape(b, n, dk)
    vf = v.reshape(b, n, dv)
    tb = min(tile_b, b)
    pad = -b % tb
    if pad:
        qf = jnp.pad(qf, ((0, pad), (0, 0), (0, 0)))
        kf = jnp.pad(kf, ((0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, pad), (0, 0), (0, 0)))
    out = block_attention_fwd(qf, kf, vf, heads=heads, tile_b=tb,
                              interpret=interpret)
    if pad:
        out = out[:b]
    return out.reshape(*lead, n, dv)
