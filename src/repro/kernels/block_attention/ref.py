"""Pure-jnp oracle for the hyper-block attention kernel (paper Eqs. 2-3).

Plain softmax self-attention over the k block embeddings of each hyper-block:
q/k/v are (B, n, d) with n = blocks-per-hyper-block (tiny, <= 16) and B huge.
Multi-head capable; heads=1 is the paper's configuration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def block_attention_ref(q: Array, k: Array, v: Array, *, heads: int = 1) -> Array:
    b, n, dk = q.shape
    dv = v.shape[-1]
    hq = q.reshape(b, n, heads, dk // heads)
    hk = k.reshape(b, n, heads, dk // heads)
    hv = v.reshape(b, n, heads, dv // heads)
    scores = jnp.einsum("bqhd,bkhd->bhqk", hq, hk) / jnp.sqrt(dk // heads)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", w, hv)
    return ctx.reshape(b, n, dv)
