"""FlashAttention Pallas TPU kernel: online-softmax causal GQA with optional
sliding window.

Tiling (DESIGN.md §4): the grid is (B, H, S/bq, T/bk) with the kv-block axis
innermost and *sequential* ("arbitrary" dimension semantics) so the running
softmax statistics (m, l) and the fp32 context accumulator live in VMEM
scratch across kv iterations.  Query/key blocks are (bq, hd)/(bk, hd) VMEM
tiles — hd (64–128) and bq/bk (128) are MXU-aligned.  GQA is expressed in the
index maps: query head h reads kv head h // (H // KV), so KV tiles are
streamed once per q-head group without materializing the repeated heads in
HBM.  Softmax numerics are fp32 on-chip regardless of I/O dtype; fully-masked
kv blocks (beyond the causal frontier or the sliding window) are skipped with
``pl.when`` so the MXU never sees them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i, *,
                 causal: bool, window: int, bq: int, bk: int,
                 seq_q: int, seq_kv: int, scale: float):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(jk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    # absolute time indices of this tile (queries suffix-aligned to kv end)
    off = seq_kv - seq_q
    tq = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + off
    tk = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: is any (tq, tk) pair in this tile live?
    q_last = iq * bq + bq - 1 + off
    q_first = iq * bq + off
    k_first = jk * bk
    k_last = jk * bk + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_first <= q_last)
    if window:
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (tk < seq_kv)
        if causal:
            mask &= tk <= tq
        if window:
            mask &= tq - tk < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_i[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        # explicit mask multiply: rows fully masked in this tile would other-
        # wise see exp(NEG_INF - NEG_INF) = 1 and corrupt the accumulator.
        p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
        l_i[...] = l_i[...] * alpha + jnp.sum(p, axis=-1)
        m_i[...] = m_new
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(jk == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_i[...], 1e-30)[:, None]
        o_ref[0, 0, ...] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0, bq: int = 128, bk: int = 128,
                        true_q: int | None = None, true_kv: int | None = None,
                        interpret: bool = False) -> Array:
    """q: (B, H, S, hd), k/v: (B, KV, T, hd) — head-major layout.

    The public wrapper (``ops.flash_attention``) transposes from the model's
    (B, S, H, hd) layout and pads S/T to tile multiples; ``true_q``/``true_kv``
    carry the unpadded lengths so padded keys are masked exactly in-kernel.
    """
    b, h, s, hd = q.shape
    kvh, t = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    grid = (b, h, s // bq, t // bk)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, bq=bq, bk=bk,
        seq_q=true_q or s, seq_kv=true_kv or t, scale=1.0 / (hd ** 0.5))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, hh, ii, jj, g=group: (bb, hh // g, jj, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, hh, ii, jj, g=group: (bb, hh // g, jj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
