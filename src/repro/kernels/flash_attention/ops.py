"""Jit'd public wrapper around the FlashAttention Pallas kernel.

Accepts the model layout (B, S, H, hd) / (B, T, KV, hd), transposes to the
kernel's head-major layout, pads sequence lengths to tile multiples (padding
keys are masked inside the kernel via absolute-time bounds) and falls back to
interpret mode off-TPU so the same call sites run everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd

Array = jax.Array


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> Array:
    """q: (B, S, H, hd), k/v: (B, T, KV, hd) -> (B, S, H, hd)."""
    if interpret is None:
        interpret = _should_interpret()
    b, s, h, hd = q.shape
    t = k.shape[1]

    bq_ = min(bq, max(s, 8))
    bk_ = min(bk, max(t, 8))
    s_pad = -s % bq_
    t_pad = -t % bk_

    qh = jnp.moveaxis(q, 2, 1)                       # (B, H, S, hd)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    if s_pad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    if t_pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, t_pad), (0, 0)))

    # Padded kv positions have absolute time >= true_kv and are masked
    # in-kernel; padded q rows produce garbage rows that are sliced away.
    out = flash_attention_fwd(qh, kh, vh, causal=causal, window=window,
                              bq=bq_, bk=bk_, true_q=s, true_kv=t,
                              interpret=interpret)
    out = out[:, :, :s] if s_pad else out
    return jnp.moveaxis(out, 1, 2)
