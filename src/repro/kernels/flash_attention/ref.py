"""Pure-jnp oracle for the flash-attention kernel.

Grouped-query attention over (B, S, H, hd) queries and (B, T, KV, hd) keys and
values, fp32 softmax, optional causal and sliding-window masks.  This is the
semantics the Pallas kernel must reproduce bit-for-bit (up to fp accumulation
order) and what the CPU fallback in ``repro.models.attention`` computes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int = 0) -> Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    i = jnp.arange(s)[:, None] + (t - s)       # query absolute time (suffix align)
    j = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (j <= i)
    if window:
        mask = mask & (i - j < window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return ctx.reshape(b, s, h, hd)
