"""Fused GAE projection Pallas kernel: c = R @ U and c2 = c^2 in one pass.

This is the MXU hot-spot of the GAE encoder (DESIGN.md §4): every block
residual is projected onto the PCA basis (paper Eq. 9) and the squared
coefficients — the sort key of Algorithm 1 — are produced in the same VMEM
round-trip, so the (N, D) coefficient tensor is squared before it ever leaves
the chip.

Tiling: grid (N/tn, D/td, D/tk) with the contraction axis innermost
(sequential); an fp32 VMEM accumulator carries the partial dot products.  The
full basis never needs to be resident (unlike a naive "keep U in VMEM" port):
for XGC's D = 1521 the basis tile stream is (tk, td) = (512, 512) = 1 MB.
MXU-aligned tiles; both outputs are written on the final contraction step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _gae_project_kernel(r_ref, u_ref, c_ref, c2_ref, acc):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        r_ref[...].astype(jnp.float32), u_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _finalize():
        c = acc[...]
        c_ref[...] = c.astype(c_ref.dtype)
        c2_ref[...] = jnp.square(c).astype(c2_ref.dtype)


def gae_project_fwd(residuals: Array, basis: Array, *, tn: int = 256,
                    td: int = 512, tk: int = 512,
                    interpret: bool = False) -> tuple[Array, Array]:
    """residuals: (N, D), basis: (D, Dout). Shapes must divide the tiles
    (wrapper pads). Returns (c, c2) fp32."""
    n, d = residuals.shape
    dout = basis.shape[1]
    tn = min(tn, n)
    td = min(td, dout)
    tk = min(tk, d)
    assert n % tn == 0 and dout % td == 0 and d % tk == 0, (n, d, dout, tn, td, tk)
    grid = (n // tn, dout // td, d // tk)
    return pl.pallas_call(
        _gae_project_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tn, tk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tk, td), lambda i, j, k: (k, j))],
        out_specs=[pl.BlockSpec((tn, td), lambda i, j, k: (i, j)),
                   pl.BlockSpec((tn, td), lambda i, j, k: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n, dout), jnp.float32),
                   jax.ShapeDtypeStruct((n, dout), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((tn, td), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(residuals, basis)
