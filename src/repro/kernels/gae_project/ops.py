"""Jit'd public wrapper around the fused GAE projection kernel.

Pads (N, D) to tile multiples — zero padding is exact for a matmul — and
slices the outputs back; interprets off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gae_project.kernel import gae_project_fwd

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("tn", "td", "tk", "interpret"))
def gae_project(residuals: Array, basis: Array, *, tn: int = 256,
                td: int = 512, tk: int = 512,
                interpret: bool | None = None) -> tuple[Array, Array]:
    """residuals: (N, D), basis: (D, Dout) -> (c, c2), both (N, Dout) fp32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = residuals.shape
    dout = basis.shape[1]
    tn_ = min(tn, max(n, 8))
    td_ = min(td, max(dout, 8))
    tk_ = min(tk, max(d, 8))
    pn, pd, pdo = -n % tn_, -d % tk_, -dout % td_
    r = jnp.pad(residuals, ((0, pn), (0, pd))) if (pn or pd) else residuals
    u = jnp.pad(basis, ((0, pd), (0, pdo))) if (pd or pdo) else basis
    c, c2 = gae_project_fwd(r, u, tn=tn_, td=td_, tk=tk_, interpret=interpret)
    if pn or pdo:
        c, c2 = c[:n, :dout], c2[:n, :dout]
    return c, c2
