"""Pure-jnp oracle for the fused GAE projection kernel (paper Eq. 9 + the
c_k^2 ranking input of Algorithm 1): c = r @ U and c2 = c*c in one pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def gae_project_ref(residuals: Array, basis: Array) -> tuple[Array, Array]:
    """residuals: (N, D), basis: (D, D) columns = principal vectors.

    Returns (c, c2) with c = residuals @ basis  (= U^T r per block, Eq. 9).
    """
    c = residuals.astype(jnp.float32) @ basis.astype(jnp.float32)
    return c, jnp.square(c)
