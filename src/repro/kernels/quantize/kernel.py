"""Fused quantization Pallas kernel (paper Sec. II-E).

One HBM read of x produces all three tensors the compressor consumes: the
int32 bin index (entropy-coding input), the dequantized center value (what the
decoder will reconstruct — fed straight into the downstream residual), and the
squared quantization error (the `(c - q(c))^2` term of the one-shot GAE
selection, DESIGN.md §4.1).  Unfused, these are three elementwise passes over
HBM; fused they are one read + three writes at VPU throughput.

Elementwise, so tiling is trivial: 2-D tiles over a flattened-to-2D view,
"parallel" semantics, bin_size as a static compile-time constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _quantize_kernel(x_ref, q_ref, deq_ref, err2_ref, *, bin_size: float):
    x = x_ref[...].astype(jnp.float32)
    q = jnp.round(x / bin_size)
    deq = q * bin_size
    q_ref[...] = q.astype(jnp.int32)
    deq_ref[...] = deq.astype(deq_ref.dtype)
    err2_ref[...] = jnp.square(x - deq)


def quantize_fused_fwd(x: Array, *, bin_size: float, tile: tuple[int, int] = (256, 512),
                       interpret: bool = False) -> tuple[Array, Array, Array]:
    """x: (R, C) with tile-divisible shape (wrapper pads)."""
    r, c = x.shape
    tr = min(tile[0], r)
    tc = min(tile[1], c)
    assert r % tr == 0 and c % tc == 0, (x.shape, tile)
    kernel = functools.partial(_quantize_kernel, bin_size=bin_size)
    return pl.pallas_call(
        kernel,
        grid=(r // tr, c // tc),
        in_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((tr, tc), lambda i, j: (i, j))] * 3,
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype),
                   jax.ShapeDtypeStruct((r, c), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
