"""Jit'd public wrapper around the fused quantize kernel: any input shape,
padded 2-D tiling underneath, interpret off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_fused_fwd

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("bin_size", "interpret"))
def quantize_fused(x: Array, bin_size: float,
                   interpret: bool | None = None) -> tuple[Array, Array, Array]:
    """x: any shape -> (q int32, deq x.dtype, err2 fp32), all shaped like x."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    flat = x.reshape(-1)
    c = min(512, flat.size)
    pad = -flat.size % c
    if pad:
        flat = jnp.pad(flat, (0, pad))
    x2 = flat.reshape(-1, c)
    q, deq, err2 = quantize_fused_fwd(x2, bin_size=float(bin_size),
                                      interpret=interpret)
    q, deq, err2 = (t.reshape(-1)[:x.size].reshape(shape) for t in (q, deq, err2))
    return q, deq, err2
