"""Pure-jnp oracle for the fused quantize kernel (paper Sec. II-E uniform
binning): bin index, dequantized center value, and squared quantization error
in a single pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_fused_ref(x: Array, bin_size: float) -> tuple[Array, Array, Array]:
    """x: any shape float -> (q int32, deq same-dtype, err2 fp32)."""
    q = jnp.round(x / bin_size).astype(jnp.int32)
    deq = (q.astype(jnp.float32) * bin_size).astype(x.dtype)
    err2 = jnp.square(x.astype(jnp.float32) - deq.astype(jnp.float32))
    return q, deq, err2
