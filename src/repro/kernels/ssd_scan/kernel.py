"""SSD (Mamba-2) chunked-scan Pallas kernel.

The SSD layer is exactly the structure a TPU likes (DESIGN.md §4): chunk-local
quadratic math = three MXU matmuls per (Q, P/N) tile, plus an O(S/Q)
inter-chunk recurrence with a tiny (P, N) state.  The GPU reference
implementation splits these into separate kernels with the state bounced
through HBM; here the grid is (B, H, S/Q) with the *chunk axis sequential* and
the running state h (P, N) carried in fp32 VMEM scratch across grid steps —
the state never touches HBM except for the single final write.

Per grid step, resident in VMEM: x (Q, P), dt (Q,), B/C (Q, N), the (Q, Q)
intra-chunk decay matrix, and the state (P, N).  At Q = 256, P = 64, N = 128
that's ~0.6 MB — far under budget, so multiple heads' programs can overlap
DMA with compute.

All math fp32 on-chip (exp/cumsum numerics); I/O in the model's compute dtype.
GQA-style B/C group sharing (G < H) is expressed in the index maps, like the
flash kernel's KV maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref, h_acc):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_acc[...] = jnp.zeros_like(h_acc)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))    # scalar
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (Q, N)

    xdt = x * dt[:, None]
    cum = jnp.cumsum(dt * a)                          # (Q,)

    # intra-chunk: scores(s,t) = (c_s . b_t) exp(cum_s - cum_t) for t <= s
    diff = cum[:, None] - cum[None, :]
    q = x.shape[0]
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cum_s) C_s . h_in   (h_in = state entering chunk)
    h_in = h_acc[...]                                 # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h = exp(cum_Q) h_in + sum_t exp(cum_Q - cum_t) b_t xdt_t
    edge = jnp.exp(cum[-1] - cum)                     # (Q,)
    cstate = jax.lax.dot_general(xdt * edge[:, None], b,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (P, N)
    h_acc[...] = jnp.exp(cum[-1]) * h_in + cstate

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0, ...] = h_acc[...]


def ssd_scan_fwd(x: Array, dt: Array, a_log: Array, b: Array, c: Array, *,
                 chunk: int, interpret: bool = False) -> tuple[Array, Array]:
    """x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,)  b,c: (B,S,G,N), S % chunk == 0.
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0 and h % g == 0, (s, chunk, h, g)
    rep = h // g
    grid = (bsz, h, s // chunk)

    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bb, hh, cc, r=rep: (bb, cc, hh // r, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bb, hh, cc, r=rep: (bb, cc, hh // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
                   jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b, c)
