"""Jit'd public wrapper around the SSD chunked-scan kernel.

Pads S up to a chunk multiple when needed (padded steps use dt = 0, which is
an exact no-op for both the output rows we discard and the carried state:
decay exp(0)=1, input contribution x*dt = 0) and interprets off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: Array, dt: Array, a_log: Array, b: Array, c: Array, *, chunk: int,
        interpret: bool | None = None) -> tuple[Array, Array]:
    """x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,)  b,c: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, s, h, p = x.shape
    q = min(chunk, s)
    pad = -s % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))     # dt=0 => exact no-op
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_scan_fwd(x, dt, a_log, b, c, chunk=q, interpret=interpret)
    if pad:
        y = y[:, :s]
    return y, state
