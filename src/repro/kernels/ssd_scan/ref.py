"""Pure-jnp oracle for the SSD (Mamba-2 state-space duality) scan kernel.

Identical math to ``repro.models.ssd.ssd_ref`` but kept self-contained here so
kernel tests depend only on the kernel package.  Computes, per head with
scalar decay A = -exp(A_log):

    y_s = sum_{t<=s} C_s^T B_t (dt_t x_t) exp(cum_s - cum_t) ,

chunked: quadratic attention-like math inside chunks + an inter-chunk state
recurrence carrying h in (P, N) per (batch, head).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_scan_ref(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                 chunk: int) -> tuple[Array, Array]:
    """x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,)  b,c: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    xdt = x.astype(jnp.float32) * dt32[..., None]
    cum = jnp.cumsum((dt32 * a).reshape(bsz, nc, chunk, h), axis=2)
    xc = xdt.reshape(bsz, nc, chunk, h, p)
    bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)

    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcshn,bcthn->bcsth", cc, bc) * decay
    y = jnp.einsum("bcsth,bcthp->bcshp", scores, xc)

    edge = jnp.exp(cum[:, :, -1:, :] - cum)
    cstate = jnp.einsum("bcth,bcthn,bcthp->bchpn", edge, bc, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def scan_fn(carry, inp):
        cs, cd = inp
        return carry * cd[:, :, None, None] + cs, carry

    final, h_in = jax.lax.scan(scan_fn, jnp.zeros((bsz, h, p, n), jnp.float32),
                               (cstate.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcsh,bcshn,bchpn->bcshp", jnp.exp(cum), cc, h_in)
    y = (y + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final
