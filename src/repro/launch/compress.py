"""Compression driver — the paper's pipeline end-to-end on a synthetic
dataset with the exact S3D/E3SM/XGC geometry: fit HBAE+BAE, compress with a
user error bound, verify the per-block guarantee, report CR + NRMSE.

  python -m repro.launch.compress --dataset s3d --tau 0.5 --quick
  python -m repro.launch.compress --dataset s3d --tau 0.5 --quick \
      --out /tmp/a.rba --verify

``--out`` writes the durable .rba container (atomic, digest-protected; see
docs/ARCHIVE_FORMAT.md); ``--verify`` re-reads it from disk and re-checks the
tau guarantee against the freshly decoded bytes.  Guarantee or verification
failures exit nonzero with a report instead of a bare assert.

``--stream`` runs the pipelined compress path (repro.stream): host GAE/
entropy coding of chunk *i* overlaps the device stage of chunk *i+1*, and
with ``--out`` finished chunk sections stream to disk as they complete
(crash-safe ``<out>.partial``, atomic finalize).  The resulting container is
byte-identical to the batch path; see docs/STREAMING.md.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import get_compressor_config
from repro.core import exec as exec_mod
from repro.core.errors import ArchiveError, ConfigError
from repro.core.options import CompressOptions
from repro.core.pipeline import HierarchicalCompressor
from repro.data import synthetic
from repro.data.blocks import nrmse


def _max_block_err(hyperblocks: np.ndarray, recon: np.ndarray,
                   d_gae: int) -> np.ndarray:
    x = hyperblocks.reshape(-1, d_gae)
    r = recon.reshape(-1, d_gae)
    return np.linalg.norm(x - r, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="s3d", choices=("s3d", "e3sm", "xgc"))
    ap.add_argument("--tau", type=float, default=0.5,
                    help="per-block l2 bound (normalized domain)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller field + fewer epochs (CI-speed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default="", help="write the fitted model "
                    "(manifest+npz, hash-verified on load)")
    ap.add_argument("--out", default="",
                    help="write the compressed archive container (.rba)")
    ap.add_argument("--verify", action="store_true",
                    help="re-read --out from disk and re-check the guarantee")
    ap.add_argument("--chunk-hyperblocks", type=int, default=64,
                    help="container stripe width (corruption blast radius)")
    ap.add_argument("--epochs-scale", type=float, default=None,
                    help="scale train epochs (e.g. 0.1 for smoke tests)")
    ap.add_argument("--stream", action="store_true",
                    help="pipelined compress (device/host overlap); with "
                    "--out, chunk sections stream to disk as they finish")
    ap.add_argument("--queue-depth", type=int, default=2,
                    help="--stream inter-stage queue bound (backpressure)")
    ap.add_argument("--retries", type=int, default=None,
                    help="--stream fault tolerance: per-item transient-"
                    "failure retries (seeded backoff); enables the "
                    "quarantine fallback for permanently failing stripes")
    ap.add_argument("--stage-deadline", type=float, default=None,
                    help="--stream per-attempt watchdog deadline in seconds "
                    "for the compute stages (implies --retries)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="--stream chaos drill: inject seeded transient "
                    "faults into the live pipeline (implies fault "
                    "tolerance); the run must still honor tau")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="shard the fused compress/decompress stage "
                    "programs over an N-device mesh (hyper-block data "
                    "axis); archives stay byte-identical to single-device "
                    "runs.  On CPU, force virtual devices with "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    args = ap.parse_args(argv)
    if args.verify and not args.out:
        ap.error("--verify requires --out")
    if (args.retries is not None or args.stage_deadline is not None
            or args.chaos is not None) and not args.stream:
        ap.error("--retries/--stage-deadline/--chaos require --stream")
    try:
        # the ONE configuration object both compress paths consume; a bad
        # combination dies here as a typed ConfigError, not mid-run
        opts = CompressOptions(
            tau=args.tau, chunk_hyperblocks=args.chunk_hyperblocks,
            stream=args.stream, queue_depth=args.queue_depth,
            retries=args.retries, stage_deadline_s=args.stage_deadline,
            chaos_seed=args.chaos, mesh=args.mesh)
        if opts.mesh is not None:
            from repro.parallel.mesh_exec import resolve_mesh
            resolve_mesh(opts.mesh)     # fail fast on impossible meshes
    except ConfigError as e:
        ap.error(str(e))

    cfg, hyperblocks = synthetic.make_dataset(args.dataset, quick=args.quick,
                                              seed=args.seed,
                                              epochs_scale=args.epochs_scale)
    print(f"{args.dataset}: {hyperblocks.shape[0]} hyper-blocks of "
          f"(k={hyperblocks.shape[1]}, D={hyperblocks.shape[2]})")

    t0 = time.time()
    comp = HierarchicalCompressor(cfg).fit(
        hyperblocks, seed=args.seed,
        log=lambda s, l: print(f"  step {s}: mse {l:.3e}"))
    print(f"fit in {time.time() - t0:.1f}s")

    exec_mod.reset_stage_stats()
    streamed_bytes = 0
    if opts.stream:
        from repro.stream import stream_compress
        try:
            # fault tolerance + chaos arm themselves from opts (retries /
            # stage_deadline_s / chaos_seed)
            result = stream_compress(comp, hyperblocks, options=opts,
                                     out_path=args.out or None)
        except OSError as e:
            print(f"ERROR: streaming write failed: {e}", file=sys.stderr)
            return 3
        archive, streamed_bytes = result.archive, result.bytes_written
        s = result.stats
        print(f"stream: {s.n_items} items -> {len(archive.chunks)} chunks "
              f"in {s.wall_s:.2f}s, device/host overlap {s.overlap_s:.2f}s "
              f"({s.overlap_efficiency() * 100:.0f}% of wall), "
              f"queue high-water {s.queue_high_water}")
        if opts.fault_tolerant():
            print(f"fault tolerance: {s.total_retries()} retries "
                  f"{dict(s.retries)}, deadline hits "
                  f"{dict(s.deadline_hits)}, failovers {dict(s.failovers)}")
        if opts.chaos_seed is not None:
            print(f"chaos injected: {result.chaos_injected}")
        if result.quarantined:
            print(f"QUARANTINED {len(result.quarantined)} chunk(s) "
                  f"{result.quarantined}: re-encoded as lossless verbatim "
                  f"fallback (tau holds trivially)")
            for ci in result.quarantined:
                print(f"  chunk {ci}: {result.quarantine_reasons.get(ci, '?')}")
    else:
        archive = comp.compress(hyperblocks, options=opts)
    recon = comp.decompress(archive, mesh=opts.mesh)
    print("-- hot-path stage throughput --")
    print(exec_mod.stats_summary())

    # hard per-block guarantee check
    d_gae = cfg.gae_block_elems or cfg.block_elems
    errs = _max_block_err(hyperblocks, recon, d_gae)
    if float(errs.max()) > args.tau * (1 + 1e-5):
        bad = int(np.sum(errs > args.tau * (1 + 1e-5)))
        print(f"ERROR: tau guarantee violated on {bad}/{errs.size} GAE "
              f"blocks (max l2 {errs.max():.6f} > tau={args.tau})",
              file=sys.stderr)
        return 2

    print(f"compression ratio: {archive.compression_ratio():.1f}x  "
          f"(+model cost: "
          f"{archive.compression_ratio(comp.model_bytes()):.1f}x)")
    print(f"NRMSE: {nrmse(hyperblocks, recon):.3e}")
    print(f"max per-block l2: {errs.max():.4f} <= tau={args.tau}")

    if args.out:
        if args.stream:
            # already on disk: the streaming writer finalized it chunk by
            # chunk during compress
            nbytes = streamed_bytes
            print(f"container streamed to {args.out} "
                  f"({nbytes:,} bytes = {len(archive.chunks)} chunks; "
                  f"on-disk ratio {hyperblocks.size * 4 / nbytes:.1f}x)")
        else:
            from repro.runtime import archive_io
            try:
                nbytes = archive_io.write_archive(archive, args.out)
            except OSError as e:
                print(f"ERROR: cannot write container: {e}", file=sys.stderr)
                return 3
            print(f"container written to {args.out} "
                  f"({nbytes:,} bytes = {len(archive.chunks)} chunks; "
                  f"on-disk ratio {hyperblocks.size * 4 / nbytes:.1f}x)")
    if args.verify:
        from repro.runtime import archive_io
        try:
            archive2 = archive_io.read_archive(args.out)
            # same mesh as the first decode: bit-exact comparability
            recon2 = comp.decompress(archive2, mesh=opts.mesh)
        except ArchiveError as e:
            print(f"ERROR: verification re-read failed: {e}", file=sys.stderr)
            return 3
        errs2 = _max_block_err(hyperblocks, recon2, d_gae)
        if not np.array_equal(recon2, recon):
            print("ERROR: on-disk decode differs from in-memory decode",
                  file=sys.stderr)
            return 3
        if float(errs2.max()) > args.tau * (1 + 1e-5):
            print(f"ERROR: tau guarantee violated after disk round-trip "
                  f"(max l2 {errs2.max():.6f})", file=sys.stderr)
            return 3
        print(f"verify OK: disk round-trip bit-exact, "
              f"max per-block l2 {errs2.max():.4f} <= tau={args.tau}")
    if args.save:
        comp.save(args.save)
        print(f"model saved to {args.save}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
