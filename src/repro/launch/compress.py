"""Compression driver — the paper's pipeline end-to-end on a synthetic
dataset with the exact S3D/E3SM/XGC geometry: fit HBAE+BAE, compress with a
user error bound, verify the per-block guarantee, report CR + NRMSE.

  python -m repro.launch.compress --dataset s3d --tau 0.5 --quick
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_compressor_config
from repro.core.pipeline import HierarchicalCompressor
from repro.data import synthetic
from repro.data.blocks import nrmse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="s3d", choices=("s3d", "e3sm", "xgc"))
    ap.add_argument("--tau", type=float, default=0.5,
                    help="per-block l2 bound (normalized domain)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller field + fewer epochs (CI-speed)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    cfg, hyperblocks = synthetic.make_dataset(args.dataset, quick=args.quick,
                                              seed=args.seed)
    print(f"{args.dataset}: {hyperblocks.shape[0]} hyper-blocks of "
          f"(k={hyperblocks.shape[1]}, D={hyperblocks.shape[2]})")

    t0 = time.time()
    comp = HierarchicalCompressor(cfg).fit(
        hyperblocks, seed=args.seed,
        log=lambda s, l: print(f"  step {s}: mse {l:.3e}"))
    print(f"fit in {time.time() - t0:.1f}s")

    archive = comp.compress(hyperblocks, tau=args.tau)
    recon = comp.decompress(archive)

    # hard per-block guarantee check
    d_gae = cfg.gae_block_elems or cfg.block_elems
    x = hyperblocks.reshape(-1, d_gae)
    r = recon.reshape(-1, d_gae)
    errs = np.linalg.norm(x - r, axis=1)
    assert float(errs.max()) <= args.tau * (1 + 1e-5), errs.max()

    print(f"compression ratio: {archive.compression_ratio():.1f}x  "
          f"(+model cost: "
          f"{archive.compression_ratio(comp.model_bytes()):.1f}x)")
    print(f"NRMSE: {nrmse(hyperblocks, recon):.3e}")
    print(f"max per-block l2: {errs.max():.4f} <= tau={args.tau}")
    if args.save:
        comp.save(args.save)
        print(f"model saved to {args.save}")


if __name__ == "__main__":
    main()
