import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (architecture x input-shape x
mesh) cell on the production meshes and dump the roofline inputs.

This is how the distribution config is proven coherent without hardware
(DESIGN.md §6): a cell FAILS here on sharding mismatch, OOM-at-compile, or an
unsupported collective — all bugs in our system, not environment artifacts.

Per cell the artifact JSON records:
  * ``memory_analysis``  — per-device argument/output/temp bytes (fits-HBM
    proof; XLA reports post-SPMD per-partition sizes),
  * ``cost_analysis``    — per-device HLO FLOPs + bytes accessed,
  * ``collectives``      — bytes + op counts parsed from the partitioned HLO
    (cost_analysis does not expose collective traffic),
  * ``model_flops``      — analytic 6·N·D (6·N_active·D for MoE) for the
    useful-compute ratio.

Cost fidelity: cells lower with ``scan_layers=False`` (unrolled stacks)
because XLA counts while-loop bodies ONCE — a scanned 62-layer stack would
under-report FLOPs and collective bytes by 62x (DESIGN.md §8).

Run:  python -m repro.launch.dryrun --all            (spawns per-cell procs)
      python -m repro.launch.dryrun --cell qwen2-1.5b:train_4k:single
      python -m repro.launch.dryrun --arch mamba2-370m --mesh multi

(No ``from __future__ import annotations`` here: the XLA_FLAGS lines above
must be the first statements of the module, before any import.)
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, LM_SHAPES, get_config, get_shape
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.parallel import sharding as shd
from repro.parallel.collectives import collective_bytes
from repro.train import optim
from repro.train.loop import TrainState, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# TPU v5e-class constants (roofline; see benchmarks/roofline.py)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


def make_run_config(shape: ShapeConfig, multi_pod: bool, **overrides) -> RunConfig:
    # Single-pod cells unroll layer stacks for cost-faithful HLO (roofline
    # reads them; module docstring).  Multi-pod cells keep the scanned stacks:
    # their job is proving the pod axis shards + memory fit, and scan compiles
    # ~depth-times faster — the roofline table is single-pod only.
    base = dict(tp=16, dp=32 if multi_pod else 16,
                param_dtype="float32" if shape.kind == "train" else "bfloat16",
                compute_dtype="bfloat16",
                remat=shape.kind == "train",
                scan_layers=multi_pod,
                use_flash_kernel=False)      # jnp path: Pallas is TPU-only
    base.update(overrides)
    return RunConfig(**base)


def _shardings(mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda s: isinstance(s, P))


def _dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _count_params(params_shape, cfg: ModelConfig) -> dict:
    """Total + active (MoE-aware) parameter counts for MODEL_FLOPS."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    total = active = embed = 0
    for path, leaf in flat:
        keys = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += n
        if "embed/w" in keys and "unembed" not in keys:
            embed += n
            continue
        if cfg.n_experts and "moe/w_" in keys:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return {"total": total, "active": int(active), "embed_table": embed}


def _record(compiled, lowered, *, n_devices: int) -> dict:
    ca = compiled.cost_analysis()
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    col = collective_bytes(txt)
    return {
        "n_devices": n_devices,
        "flops_per_device": float(ca.get("flops", -1.0)),
        "bytes_per_device": float(ca.get("bytes accessed", -1.0)),
        "utilization_transcendentals": float(ca.get("transcendentals", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "collectives": col,
        "hlo_chars": len(txt),
    }


# ---------------------------------------------------------------------------
# cell builders: return (fn, example_args_sds, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape: ShapeConfig, multi_pod: bool,
                  run_overrides: Optional[dict] = None):
    cfg = get_config(arch)
    run = make_run_config(shape, multi_pod, **(run_overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = _dp_axes(multi_pod)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    api = registry.get_model(cfg)
    params_shape = registry.params_specs(cfg, run)
    if shape.kind != "train":
        # serving runs on cast weights (RunConfig.param_dtype): halves the
        # param bytes and every FSDP gather vs the fp32 training master
        pdt = jnp.dtype(run.param_dtype)
        params_shape = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, pdt)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, params_shape)
    pspecs = shd.param_partition_specs(
        params_shape, fsdp_axis="data", fsdp_size=mesh.shape["data"],
        tp_size=mesh.shape["model"])
    nparams = _count_params(params_shape, cfg)

    if shape.kind == "train":
        opt = optim.adamw(optim.warmup_cosine_schedule(3e-4, 2000, 100_000),
                          weight_decay=0.1, max_grad_norm=1.0)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        state_sds = TrainState(params=params_shape, opt=opt_shape, gc=None,
                               step=jax.ShapeDtypeStruct((), jnp.int32))
        state_specs = TrainState(
            params=pspecs, opt=type(opt_shape)(step=P(), mu=pspecs, nu=pspecs),
            gc=None, step=P())
        batch_sds = registry.train_batch_specs(cfg, run, shape)
        batch_specs = {k: P(dp, *([None] * (len(v.shape) - 1)))
                       for k, v in batch_sds.items()}
        fn = make_train_step(cfg, run, opt)
        args = (state_sds, batch_sds)
        in_specs = (state_specs, batch_specs)
        out_specs = (state_specs, None)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        batch_sds = registry.prefill_specs(cfg, run, shape)
        batch_specs = {k: P(dp, *([None] * (len(v.shape) - 1)))
                       for k, v in batch_sds.items()}

        def fn(params, batch):
            extra = {k: v for k, v in batch.items() if k != "tokens"}
            return api.forward(params, cfg, run, batch["tokens"], **extra)
        args = (params_shape, batch_sds)
        in_specs = (pspecs, batch_specs)
        out_specs = P(dp, None, "model")
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        spec_d = registry.decode_specs(cfg, run, shape)
        state_specs = shd.decode_state_specs(
            spec_d["state"], multi_pod, batch=shape.global_batch,
            dp_size=dp_total, seq_len=shape.seq_len,
            tp_size=mesh.shape["model"])
        tok_spec = (P(dp, None) if shape.global_batch >= dp_total
                    else P(None, None))

        def fn(params, token, state):
            return api.decode_step(params, cfg, run, token, state)
        args = (params_shape, spec_d["token"], spec_d["state"])
        in_specs = (pspecs, tok_spec, state_specs)
        logits_spec = (P(dp, None, "model") if shape.global_batch >= dp_total
                       else P(None, None, "model"))
        out_specs = (logits_spec, state_specs)
        tokens = shape.global_batch
    return dict(cfg=cfg, run=run, mesh=mesh, fn=fn, args=args,
                in_specs=in_specs, out_specs=out_specs, nparams=nparams,
                tokens=tokens)


# -- compressor cells (the paper's own steps on the mesh) --------------------

COMPRESSOR_SHAPES = {
    # name: (hyper-blocks per step, k, block_elems, latent)
    "train_hb": (8192, 10, 4640, 128),     # S3D geometry (58*5*4*4 blocks)
    "gae_select": (65536, 80, 0, 0),       # GAE at 5*4*4 per-species blocks
}


def build_compressor_cell(shape_name: str, multi_pod: bool):
    from repro.core import gae as gae_mod
    from repro.core import hbae as hbae_mod
    from repro.core.training import hbae_loss

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = _dp_axes(multi_pod)
    run = make_run_config(ShapeConfig("train_4k", 0, 0, "train"), multi_pod)

    if shape_name == "train_hb":
        n, k, d, latent = COMPRESSOR_SHAPES["train_hb"]
        opt = optim.adam(1e-3)
        params_shape = jax.eval_shape(
            lambda key: hbae_mod.hbae_init(key, in_dim=d, k=k, emb=128,
                                           hidden=256, latent=latent),
            jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(opt.init, params_shape)
        pspecs = jax.tree.map(lambda _: P(), params_shape)
        ospecs = type(opt_shape)(step=P(), mu=pspecs, nu=pspecs)

        def fn(params, opt_state, x):
            loss, grads = jax.value_and_grad(hbae_loss)(params, x)
            params, opt_state, _ = opt.update(grads, opt_state, params)
            return params, opt_state, loss
        x_sds = jax.ShapeDtypeStruct((n, k, d), jnp.float32)
        args = (params_shape, opt_shape, x_sds)
        in_specs = (pspecs, ospecs, P(dp, None, None))
        out_specs = (pspecs, ospecs, P())
        nparams = {"total": sum(int(np.prod(l.shape))
                                for l in jax.tree.leaves(params_shape)),
                   "active": 0, "embed_table": 0}
        nparams["active"] = nparams["total"]
        tokens = n * k * d   # "tokens" = elements compressed per step
        analytic_flops = 6.0 * nparams["total"] * n   # fwd+bwd per hyperblock
    else:  # gae_select: distributed PCA + one-shot batched Algorithm 1
        n, d, _, _ = COMPRESSOR_SHAPES["gae_select"]

        def fn(residuals):
            cov = residuals.T @ residuals        # GSPMD all-reduces over dp
            _, vecs = jnp.linalg.eigh(cov)
            basis = vecs[:, ::-1]
            sel = gae_mod.gae_select(residuals, basis, tau=1e-2,
                                     bin_size=1e-3)
            return sel.corrected, sel.m, sel.err
        args = (jax.ShapeDtypeStruct((n, d), jnp.float32),)
        in_specs = (P(dp, None),)
        out_specs = (P(dp, None), P(dp), P(dp))
        nparams = {"total": 0, "active": 0, "embed_table": 0}
        tokens = n * d
        # analytic: project (2nd^2) + reconstruct (2nd^2) + covariance (2nd^2)
        analytic_flops = 6.0 * n * d * d
    cfg = ModelConfig(arch=f"compressor-{shape_name}", family="compressor",
                      n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0,
                      vocab=0)
    return dict(cfg=cfg, run=run, mesh=mesh, fn=fn, args=args,
                in_specs=in_specs, out_specs=out_specs, nparams=nparams,
                tokens=tokens, analytic_flops=analytic_flops)


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ARTIFACT_DIR, tag: str = "",
             run_overrides: Optional[dict] = None) -> dict:
    multi_pod = mesh_name == "multi"
    t0 = time.time()
    if arch.startswith("compressor"):
        cell = build_compressor_cell(shape_name, multi_pod)
        shape_kind = "compressor"
    else:
        shape = get_shape(shape_name)
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "skipped", "reason": why}
        cell = build_lm_cell(arch, shape, multi_pod, run_overrides)
        shape_kind = shape.kind
    mesh = cell["mesh"]
    dp_total = int(np.prod([mesh.shape[a] for a in _dp_axes(multi_pod)]))
    kv_seq = (shape_kind == "decode"
              and not arch.startswith("compressor")
              and get_shape(shape_name).global_batch < dp_total)
    with jax.set_mesh(mesh):
        with shd.activation_sharding(
                shd.activation_rules(multi_pod, sp=cell["run"].sp,
                                     kv_seq_shard=kv_seq)):
            jitted = jax.jit(cell["fn"],
                             in_shardings=_shardings(mesh, cell["in_specs"]),
                             out_shardings=_shardings(mesh, cell["out_specs"]))
            lowered = jitted.lower(*cell["args"])
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    rec = _record(compiled, lowered, n_devices=mesh.size)
    dump = os.environ.get("REPRO_DUMP_HLO")
    if dump:
        with open(dump, "w") as f:
            f.write(compiled.as_text())
    # kind-aware analytic FLOPs (benchmarks.roofline recomputes the same way)
    factor = 6.0 if shape_kind in ("train", "compressor") else 2.0
    mflops = cell.get("analytic_flops",
                      factor * cell["nparams"]["active"] * cell["tokens"])
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape_kind, "status": "ok",
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "params": cell["nparams"], "tokens_per_step": cell["tokens"],
        "model_flops": mflops,
        **rec,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{tag}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def all_cells() -> list[tuple[str, str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in LM_SHAPES:
            for mesh_name in ("single", "multi"):
                cells.append((arch, shape.name, mesh_name))
    for shape_name in COMPRESSOR_SHAPES:
        for mesh_name in ("single", "multi"):
            cells.append(("compressor", shape_name, mesh_name))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:mesh  (single process)")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--overrides", default="",
                    help='JSON RunConfig overrides, e.g. {"remat": false}')
    ap.add_argument("--jobs", type=int, default=3,
                    help="concurrent per-cell compile subprocesses")
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print(":".join(c))
        return 0

    overrides = json.loads(args.overrides) if args.overrides else None

    if args.cell:
        arch, shape, mesh = args.cell.split(":")
        try:
            r = run_cell(arch, shape, mesh, args.out, args.tag, overrides)
        except Exception:
            traceback.print_exc()
            print(f"FAIL {args.cell}")
            return 1
        print(json.dumps(r, indent=1))
        return 0

    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.mesh:
        cells = [c for c in cells if c[2] == args.mesh]

    # one subprocess per cell: fresh XLA state, crash isolation
    import concurrent.futures as cf

    def one(cell):
        arch, shape, mesh = cell
        spec = f"{arch}:{shape}:{mesh}"
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--cell", spec,
               "--out", args.out, "--tag", args.tag]
        if args.overrides:
            cmd += ["--overrides", args.overrides]
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        if proc.returncode != 0:
            return spec, None, dt, proc.stderr[-2000:]
        try:
            last = json.loads(proc.stdout[proc.stdout.index("{"):])
            status = last.get("status")
        except Exception:
            status = "ok?"
        return spec, status, dt, ""

    failures = []
    with cf.ThreadPoolExecutor(max_workers=max(args.jobs, 1)) as pool:
        for spec, status, dt, err in pool.map(one, cells):
            if status is None:
                failures.append(spec)
                print(f"[FAIL {dt:6.1f}s] {spec}\n{err}", flush=True)
            else:
                print(f"[{status:>7} {dt:6.1f}s] {spec}", flush=True)
    print(f"\n{len(cells) - len(failures)}/{len(cells)} cells passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
