"""Production meshes.  FUNCTIONS, not module-level constants — importing this
module never touches jax device state (smoke tests must keep seeing 1 CPU
device; only launch/dryrun.py forces 512 placeholder devices).

Single pod: (16, 16) ("data", "model") = 256 chips (TPU v5e-class pod).
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips; the ``pod`` axis
extends data parallelism across pods (gradient all-reduce crosses DCI) — the
standard multi-pod layout (DESIGN.md §6).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / small-scale runs / PP layouts)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Whatever this host has, as (data, model) — smoke/integration tests."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_size(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return n


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
