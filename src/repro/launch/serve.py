"""Serving driver: batched prefill+decode with continuous batching and the
compressed-KV option (runtime/kvcache).  CPU-runnable with --reduced.

  python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --requests 12
  python -m repro.launch.serve --arch mamba2-370m --reduced --kv-tau 0.05
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models.registry import get_model, reduced_config
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--kv-tau", type=float, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    run = RunConfig()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg, run)

    if cfg.family in ("audio",):
        print("note: encoder-decoder serving needs frames per request; "
              "using the batch path with random frames")
    engine = ServeEngine(cfg, run, params, batch_size=args.batch,
                         max_len=args.max_len, temperature=args.temperature,
                         kv_tau=args.kv_tau, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    outs = engine.serve(reqs)
    dt = time.time() - t0
    gen = sum(len(c.tokens) for c in outs)
    print(f"{len(outs)} completions, {gen} tokens in {dt:.1f}s "
          f"({gen / dt:.1f} tok/s, kv_tau={args.kv_tau})")
    for c in outs[:3]:
        print(f"  req {c.rid}: {c.tokens[:10].tolist()}...")


if __name__ == "__main__":
    main()
