"""LM training driver — the end-to-end path a real job runs: deterministic
data pipeline -> jitted sharded train step -> checkpoint/restart ->
failure-resilient loop.  Works on whatever devices the host has (the
production meshes are exercised AOT by launch/dryrun.py).

Examples:
  python -m repro.launch.train --arch qwen1.5-0.5b --reduced --steps 200
  python -m repro.launch.train --arch qwen2-1.5b --reduced --steps 300 \
      --grad-compression pca_ef --ckpt /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.tokens import SyntheticCorpus, TokenPipelineConfig
from repro.launch.mesh import make_host_mesh
from repro.models.registry import reduced_config
from repro.parallel import sharding as shd
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failures import ResilientRunner
from repro.train import optim
from repro.train.loop import TrainState, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "pca_ef", "gae"))
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    run = RunConfig(tp=args.tp, gradient_compression=args.grad_compression)
    mesh = make_host_mesh(model=args.tp)
    dp = mesh.shape["data"]
    assert args.batch % dp == 0

    opt = optim.adamw(optim.warmup_cosine_schedule(args.lr, 20, args.steps),
                      weight_decay=0.01, max_grad_norm=1.0)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, run, opt)
    pspecs = shd.param_partition_specs(state.params, tp_size=args.tp)
    state_specs = TrainState(
        params=pspecs, opt=type(state.opt)(step=P(), mu=pspecs, nu=pspecs),
        gc=None if state.gc is None else jax.tree.map(lambda _: P(), state.gc),
        step=P())
    batch_specs = {"tokens": P(("data",), None), "labels": P(("data",), None)}
    shards = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda s: isinstance(s, P))

    with jax.set_mesh(mesh):
        step_fn = jax.jit(make_train_step(cfg, run, opt,
                                          microbatches=args.microbatches),
                          in_shardings=(shards(state_specs), shards(batch_specs)),
                          out_shardings=(shards(state_specs), None),
                          donate_argnums=(0,))

        corpus = SyntheticCorpus(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
            seed=args.seed))

        ckpt = CheckpointManager(args.ckpt or "/tmp/repro_ckpt", retention=3)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            start, state = ckpt.restore(shardings=state_specs, mesh=mesh)
            print(f"resumed at step {start}")

        t_hist = []
        metrics = {}

        def wrapped(st, batch):
            return step_fn(st, {k: jnp.asarray(v) for k, v in batch.items()})

        runner = ResilientRunner(
            wrapped, ckpt, lambda s: iter(_gen(corpus, s)),
            save_every=args.save_every,
            on_event=lambda kind, info: print(f"[{kind}] {info}"))

        # SIGTERM (scheduler preemption) / SIGINT (ctrl-C) -> checkpoint at
        # the next step boundary and exit cleanly instead of dying mid-step.
        def _on_signal(signum, frame):
            print(f"[signal] {signal.Signals(signum).name}: preempting at "
                  "next step boundary")
            runner.request_preemption()

        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, _on_signal)

        t0 = time.time()
        state, end = runner.run(state, start, args.steps)
        dt = time.time() - t0
        ckpt.save(end, state, blocking=True)
    tok_rate = (end - start) * args.batch * args.seq / max(dt, 1e-9)
    print(f"done: steps {start}->{end}  loss={runner.stats.last_loss:.4f}  "
          f"{tok_rate:,.0f} tok/s  restores={runner.stats.restores}")


def _gen(corpus, start):
    s = start
    while True:
        yield corpus.batch_at(s)
        s += 1


if __name__ == "__main__":
    main()
