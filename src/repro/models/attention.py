"""GQA attention: full-sequence (train/prefill) and cached decode paths.

Supports: grouped-query / multi-query heads, RoPE, QKV bias (qwen1.5/qwen2),
qk-norm (qwen3), sliding-window local attention with a ring-buffer KV cache
(recurrentgemma, long-context decode), cross-attention (whisper / llama-vision),
and head padding for tensor parallelism (DESIGN.md §5).

Weight layout (matched by the partition rules in ``repro.parallel.sharding``):
    wq: (d, H, hd)   wk/wv: (d, KV, hd)   wo: (H, hd, d)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, lecun_init, rmsnorm, rmsnorm_init
from repro.parallel.sharding import constrain

Array = jax.Array

NEG_INF = -1e30


def attn_init(key, d: int, n_heads: int, n_kv: int, head_dim: int, *,
              qkv_bias: bool = False, qk_norm: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": lecun_init(kq, (d, n_heads, head_dim), fan_in=d),
        "wk": lecun_init(kk, (d, n_kv, head_dim), fan_in=d),
        "wv": lecun_init(kv, (d, n_kv, head_dim), fan_in=d),
        "wo": lecun_init(ko, (n_heads, head_dim, d), fan_in=n_heads * head_dim),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), jnp.float32)
        p["bk"] = jnp.zeros((n_kv, head_dim), jnp.float32)
        p["bv"] = jnp.zeros((n_kv, head_dim), jnp.float32)
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim)
        p["k_norm"] = rmsnorm_init(head_dim)
    return p


def _project_qkv(params: dict, x: Array, x_kv: Array, positions, theta,
                 rope: bool) -> tuple[Array, Array, Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def _gqa_scores_ctx(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd); grouped einsum without repeating KV."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return ctx.reshape(b, s, h, hd)


def full_attention(params: dict, x: Array, *, positions: Array,
                   theta: float = 1e4, causal: bool = True, window: int = 0,
                   rope: bool = True, x_kv: Optional[Array] = None,
                   use_kernel: bool = False) -> Array:
    """Train/prefill path. x: (B,S,d). ``x_kv`` enables cross-attention
    (positions apply to q only; k/v unrotated, mask full)."""
    cross = x_kv is not None
    q, k, v = _project_qkv(params, x, x_kv if cross else x, positions, theta,
                           rope and not cross)
    if cross:
        mask = None
    else:
        s = x.shape[1]
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        m = (j <= i) if causal else jnp.ones((s, s), bool)
        if window:
            m = m & (i - j < window)
        mask = m[None, None, None, :, :]
    if use_kernel and not cross:
        from repro.kernels.flash_attention import ops as fa_ops
        ctx = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    else:
        ctx = _gqa_scores_ctx(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array       # (B, S_cache, KV, hd)
    v: Array
    pos: Array     # scalar int32: number of tokens already in the cache
    window: int    # 0 = full cache; >0 = ring buffer of this size

    @staticmethod
    def zeros(batch: int, length: int, n_kv: int, head_dim: int, dtype,
              window: int = 0) -> "KVCache":
        size = min(length, window) if window else length
        return KVCache(k=jnp.zeros((batch, size, n_kv, head_dim), dtype),
                       v=jnp.zeros((batch, size, n_kv, head_dim), dtype),
                       pos=jnp.zeros((), jnp.int32), window=window)


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.k, c.v, c.pos), c.window),
    lambda window, leaves: KVCache(*leaves, window=window))


def decode_attention(params: dict, x: Array, cache: KVCache, *,
                     theta: float = 1e4, rope: bool = True,
                     kv_cross: Optional[tuple[Array, Array]] = None
                     ) -> tuple[Array, KVCache]:
    """One-token decode. x: (B,1,d).  With ``kv_cross`` (precomputed encoder
    K/V), attends those instead and leaves the cache untouched."""
    dt = x.dtype
    pos = cache.pos
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    if kv_cross is not None:
        q, _, _ = _project_qkv(params, x, x, positions, theta, rope=False)
        k, v = kv_cross
        ctx = _gqa_scores_ctx(q, k, v, mask=None)
        out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dt))
        return out, cache

    q, k_new, v_new = _project_qkv(params, x, x, positions, theta, rope)
    slot = jnp.where(cache.window > 0, pos % jnp.maximum(cache.window, 1), pos)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    # pin the updated cache's sharding: without this GSPMD's propagation can
    # settle on a replicated cache and all-gather the ENTIRE KV per step —
    # observed as 4.4 TB/device of gathers on deepseek decode_32k (§Perf)
    k = constrain(k, "kv_cache")
    v = constrain(v, "kv_cache")
    t = jnp.arange(k.shape[1])
    if cache.window:
        valid = t < jnp.minimum(pos + 1, cache.window)       # ring: all live slots
    else:
        valid = t <= pos
    mask = valid[None, None, None, None, :]
    ctx = _gqa_scores_ctx(q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["wo"].astype(dt))
    return out, KVCache(k=k, v=v, pos=pos + 1, window=cache.window)


def prefill_cache(params: dict, x: Array, *, positions: Array, theta: float,
                  rope: bool, max_len: int, window: int = 0) -> KVCache:
    """Build a cache from a full prompt (keys stored rotated)."""
    _, k, v = _project_qkv(params, x, x, positions, theta, rope)
    b, s = x.shape[0], x.shape[1]
    cache = KVCache.zeros(b, max_len, k.shape[2], k.shape[3], k.dtype, window)
    if window and s > window:
        # ring-buffer invariant: key of absolute time t lives at slot t % window
        times = jnp.arange(s - window, s)
        slots = times % window
        newk = cache.k.at[:, slots].set(k[:, -window:])
        newv = cache.v.at[:, slots].set(v[:, -window:])
    else:
        newk = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
        newv = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
    return KVCache(k=newk, v=newv, pos=jnp.asarray(s, jnp.int32), window=window)
