"""Shared model building blocks (pure JAX, params = nested dicts).

Conventions:
  * params are float32 at init; cast to RunConfig.param_dtype by the trainer.
  * all functions take explicit shapes — nothing reads global state.
  * weight layouts are chosen so partition rules can match on path names:
      ("embed", "w")        -> (vocab, d)
      ("...attn", "wq")     -> (d, H, hd)        sharded on H
      ("...mlp", "w_in")    -> (d, f)            sharded on f
      ("...moe", "w1")      -> (E, d, f)         sharded on E (or f)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def he_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (2.0 / fan_in) ** 0.5


def lecun_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / fan_in) ** 0.5


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (y * params["scale"]).astype(dtype)


def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(n: int, d: int) -> Array:
    """Fixed sinusoidal embeddings (whisper encoder)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angles = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int) -> dict:
    return {"w": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(params: dict, tokens: Array) -> Array:
    return params["w"][tokens]


def unembed_init(key, d: int, vocab: int) -> dict:
    return {"w": lecun_init(key, (d, vocab))}


def unembed(params: dict, x: Array) -> Array:
    return x @ params["w"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, f: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": lecun_init(k1, (d, f)),
            "w_in": lecun_init(k2, (d, f)),
            "w_out": lecun_init(k3, (f, d), fan_in=f)}


def swiglu(params: dict, x: Array) -> Array:
    dt = x.dtype
    gate = jax.nn.silu(x @ params["w_gate"].astype(dt))
    return (gate * (x @ params["w_in"].astype(dt))) @ params["w_out"].astype(dt)


def gelu_mlp_init(key, d: int, f: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {"w_in": lecun_init(k1, (d, f)), "b_in": jnp.zeros((f,), jnp.float32),
            "w_out": lecun_init(k2, (f, d), fan_in=f),
            "b_out": jnp.zeros((d,), jnp.float32)}


def gelu_mlp(params: dict, x: Array) -> Array:
    dt = x.dtype
    h = jax.nn.gelu(x @ params["w_in"].astype(dt) + params["b_in"].astype(dt))
    return h @ params["w_out"].astype(dt) + params["b_out"].astype(dt)


# ---------------------------------------------------------------------------
# layer-stack application: scanned (compact HLO) or unrolled (cost-faithful)
# ---------------------------------------------------------------------------

def apply_stack(body, carry, xs, *, unroll: bool):
    """Run ``body(carry, layer_slice) -> (carry, y)`` over the leading axis of
    ``xs``.

    ``unroll=False`` -> ``jax.lax.scan``: O(1) HLO size in depth (default for
    training/serving).  ``unroll=True`` -> a Python loop over layer indices:
    the compiled module contains every layer, so ``cost_analysis()`` and the
    collective-bytes sweep count each layer's FLOPs/bytes/collectives — XLA
    reports while-loop bodies ONCE, which would undercount a scanned stack by
    the trip count (launch/dryrun.py lowers with unroll=True for exactly this
    reason; see DESIGN.md §8).
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda p: p[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] == ():
        return carry, ()
    y_stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, y_stacked


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits: Array, labels: Array, vocab: int) -> Array:
    """Mean CE over all positions; labels >= vocab (padding ids) are masked."""
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab)
    nll = jnp.where(mask, logz - gold, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def chunked_ce_loss(x: Array, unembed_w: Array, labels: Array, vocab: int,
                    chunk: int, logit_mask_from: int = 0,
                    unroll: bool = False) -> Array:
    """Fused LM-head + CE, sequence-chunked (§Perf hillclimb lever).

    The baseline path materializes logits (B, S, V) in compute dtype AND an
    fp32 upcast — at (256, 4096, 152k) that is the single largest HBM tensor
    of the train step.  Here the head matmul + logsumexp + gold-gather run
    per sequence chunk inside ``lax.map``, so the live logits buffer is
    (B, chunk, V) and the full tensor never exists.  Identical math (exact,
    not an approximation); backward recomputes per-chunk logits (that trade
    is the point: logits are compute-cheap, byte-heavy).

    x: (B, S, D) final hidden states;  unembed_w: (D, V_padded);
    ``logit_mask_from``: columns >= this are padding (masked to -inf).
    """
    b, s, d = x.shape
    n = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)       # (n, B, c, D)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)        # (n, B, c)
    w = unembed_w.astype(x.dtype)
    vpad = w.shape[1]

    def one(args):
        xi, li = args                                          # (B,c,D),(B,c)
        logits = (xi @ w).astype(jnp.float32)                  # (B, c, Vpad)
        if logit_mask_from and logit_mask_from < vpad:
            col_mask = jnp.where(jnp.arange(vpad) < logit_mask_from, 0.0,
                                 -1e30)
            logits = logits + col_mask
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        mask = (li >= 0) & (li < vocab)
        nll = jnp.where(mask, logz - gold, 0.0)
        return nll.sum(), mask.sum()

    if unroll:      # cost-faithful HLO for the dry-run (DESIGN.md §4.7)
        outs = [one((xc[i], lc[i])) for i in range(n)]
        nll_total = sum(o[0] for o in outs)
        cnt_total = sum(o[1] for o in outs)
        return nll_total / jnp.maximum(cnt_total, 1)
    nlls, counts = jax.lax.map(one, (xc, lc))
    return nlls.sum() / jnp.maximum(counts.sum(), 1)
