"""Mixture-of-Experts layer with sort-based static-capacity dispatch.

Rather than the dense one-hot dispatch einsum (whose 0/1 "matmul" FLOPs dwarf
the expert FLOPs at large S — it would poison the roofline's useful-FLOPs
ratio), tokens are routed by sorting (token, slot) pairs by expert id and
gathering each expert's segment into a static (E, C, D) buffer:

    FLOPs = 2 * E * C * (3 * D * F)   with   C = ceil(T * top_k * cf / E)

i.e. proportional to *active* tokens.  Gathers/scatters are memory ops.  Over-
capacity tokens are dropped (standard "dropping" MoE semantics; capacity_factor
controls the head-room).  Expert weights are laid out (E, D, F) so EP shards
the leading expert axis (llama4: 16 experts / 16-way model axis) and falls back
to F-sharding when E doesn't divide the axis (granite: 40 experts).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import lecun_init
from repro.parallel.sharding import constrain

Array = jax.Array


def moe_init(key, d: int, f: int, n_experts: int, *, shared_expert: bool,
             shared_f: Optional[int] = None) -> dict:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": lecun_init(kr, (d, n_experts)),
        "w_gate": lecun_init(k1, (n_experts, d, f), fan_in=d),
        "w_in": lecun_init(k2, (n_experts, d, f), fan_in=d),
        "w_out": lecun_init(k3, (n_experts, f, d), fan_in=f),
    }
    if shared_expert:
        from repro.models.common import swiglu_init
        p["shared"] = swiglu_init(ks, d, shared_f or f)
    return p


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(tokens * top_k * cf / n_experts) + 1
    return max(8, min(c, tokens))


def moe_apply(params: dict, x: Array, *, top_k: int,
              capacity_factor: float = 1.25, dispatch_groups: int = 0) -> Array:
    """x: (B, S, D) -> (B, S, D).

    ``dispatch_groups`` > 1 = hierarchical dispatch (§Perf llama4 it4):
    tokens are split into G groups (sharded over the data axis) and each
    group routes/sorts/dispatches its OWN tokens with per-group capacity —
    the global 1M-token argsort + gather/scatter that otherwise forces
    cross-shard data movement becomes G independent shard-local dispatches
    (the standard per-device-capacity MoE semantics).
    """
    b, s, d = x.shape
    e = params["router"].shape[1]
    if dispatch_groups > 1:
        t = b * s
        assert t % dispatch_groups == 0
        xg = x.reshape(dispatch_groups, t // dispatch_groups, d)
        xg = constrain(xg, "tokens_grouped")
        yg = jax.vmap(lambda g: _dispatch_tokens(
            params, g, top_k=top_k, capacity_factor=capacity_factor))(xg)
        return constrain(yg, "tokens_grouped").reshape(b, s, d)
    y = _dispatch_tokens(params, x.reshape(b * s, d), top_k=top_k,
                         capacity_factor=capacity_factor)
    return y.reshape(b, s, d)


def _dispatch_tokens(params: dict, xf: Array, *, top_k: int,
                     capacity_factor: float) -> Array:
    """Sort-based dispatch over a flat (T, D) token table (module docstring)."""
    t, d = xf.shape
    e = params["router"].shape[1]
    xf = constrain(xf, "tokens_flat")
    dt = xf.dtype

    gates = jax.nn.softmax((xf @ params["router"].astype(dt)).astype(jnp.float32))
    weights, expert_idx = jax.lax.top_k(gates, top_k)            # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    c = _capacity(t, top_k, e, capacity_factor)
    flat_expert = expert_idx.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_expert)                              # stable
    sorted_expert = flat_expert[order]
    # position of each routed slot within its expert segment
    seg_starts = jnp.cumsum(jnp.bincount(sorted_expert, length=e)) - \
        jnp.bincount(sorted_expert, length=e)
    pos_in_expert = jnp.arange(t * top_k) - seg_starts[sorted_expert]
    keep = pos_in_expert < c
    token_of = order // top_k                                     # source token
    buf_slot = sorted_expert * c + jnp.where(keep, pos_in_expert, 0)

    # gather tokens into the (E*C, D) buffer; over-capacity slots target the
    # out-of-bounds index e*c and are dropped by the scatter itself
    buffer = jnp.zeros((e * c, d), dt)
    buffer = buffer.at[jnp.where(keep, buf_slot, e * c)].set(
        xf[token_of], mode="drop")
    # NOTE(§Perf llama4 iteration 1, REFUTED): pinning P("model",None,None)
    # on these buffers made GSPMD trade the dispatch all-to-all for larger
    # all-gathers (+8.9% collective) — GSPMD's own propagation picks the
    # better layout here, so the buffers are left unconstrained.
    hidden = buffer.reshape(e, c, d)

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, params["w_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", hidden, params["w_in"].astype(dt))
    out_buf = jnp.einsum("ecf,efd->ecd", gate * up, params["w_out"].astype(dt))
    out_flat = out_buf.reshape(e * c, d)

    # scatter back with combine weights
    w_of_slot = weights.reshape(-1)[order]                        # (T*k,)
    contrib = jnp.where(keep[:, None], out_flat[buf_slot] * w_of_slot[:, None]
                        .astype(dt), 0.0)
    y = jnp.zeros((t, d), dt).at[token_of].add(contrib)
    y = constrain(y, "tokens_flat")

    if "shared" in params:
        from repro.models.common import swiglu
        y = y + swiglu(params["shared"], xf)
    return y


def moe_router_stats(params: dict, x: Array, top_k: int) -> dict:
    """Load-balance diagnostics (fraction of dropped tokens, expert load)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gates = jax.nn.softmax((xf @ params["router"].astype(x.dtype)).astype(jnp.float32))
    _, expert_idx = jax.lax.top_k(gates, top_k)
    load = jnp.bincount(expert_idx.reshape(-1), length=params["router"].shape[1])
    return {"expert_load": load, "load_cv": jnp.std(load.astype(jnp.float32)) /
            jnp.maximum(jnp.mean(load.astype(jnp.float32)), 1e-9)}
