"""Arch-id -> model dispatch + ShapeDtypeStruct input specs for every
(architecture x input-shape) dry-run cell.

``input_specs`` returns exactly what ``train_step`` / ``prefill_step`` /
``serve_step`` consume — weak-type-correct stand-ins, no device allocation.
Modality frontends are stubs per the assignment: audio supplies precomputed
frame embeddings, VLM supplies precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    forward: Callable                # (params, cfg, run, tokens, ...) -> logits
    train_loss: Callable             # (params, cfg, run, batch) -> scalar
    init_decode_state: Callable      # (params, cfg, run, batch, max_len, ...) -> state
    decode_step: Callable            # (params, cfg, run, token, state) -> (logits, state)


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as m
    elif cfg.family == "hybrid":
        from repro.models import rglru as m
    elif cfg.family == "ssm":
        from repro.models import ssd as m
    elif cfg.family == "audio":
        from repro.models import whisper as m
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return ModelApi(init_params=m.init_params, forward=m.forward,
                    train_loss=m.train_loss,
                    init_decode_state=m.init_decode_state,
                    decode_step=m.decode_step)


def _f(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, run: RunConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {"tokens": _f((b, s), jnp.int32),
                             "labels": _f((b, s), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = _f((b, cfg.n_frames, cfg.d_model),
                             jnp.dtype(run.compute_dtype))
    if cfg.family == "vlm":
        batch["vision_embeds"] = _f((b, cfg.n_vision_tokens, cfg.d_model),
                                    jnp.dtype(run.compute_dtype))
    return batch


def prefill_specs(cfg: ModelConfig, run: RunConfig, shape: ShapeConfig) -> dict:
    batch = train_batch_specs(cfg, run, shape)
    del batch["labels"]
    return batch


def decode_specs(cfg: ModelConfig, run: RunConfig, shape: ShapeConfig) -> dict:
    """Token + decode-state ShapeDtypeStructs via eval_shape (no allocation)."""
    api = get_model(cfg)
    b = shape.global_batch
    params_shape = jax.eval_shape(
        lambda k: api.init_params(k, cfg, run), jax.random.PRNGKey(0))

    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = _f((b, cfg.n_frames, cfg.d_model),
                              jnp.dtype(run.compute_dtype))
    if cfg.family == "vlm":
        kwargs["vision_embeds"] = _f((b, cfg.n_vision_tokens, cfg.d_model),
                                     jnp.dtype(run.compute_dtype))

    state_shape = jax.eval_shape(
        lambda p, **kw: api.init_decode_state(p, cfg, run, b, shape.seq_len, **kw),
        params_shape, **kwargs)
    return {"token": _f((b, 1), jnp.int32), "state": state_shape,
            "params": params_shape}


def params_specs(cfg: ModelConfig, run: RunConfig):
    api = get_model(cfg)
    return jax.eval_shape(lambda k: api.init_params(k, cfg, run),
                          jax.random.PRNGKey(0))


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per assignment: the full
    configs are exercised only via the dry-run)."""
    changes: dict[str, Any] = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64, n_heads=4, n_kv_heads=min(max(1, cfg.n_kv_heads // 4), 4),
        d_ff=128 if cfg.d_ff else 0, vocab=512, head_dim=16, max_seq=512)
    if cfg.family == "moe":
        changes.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff=64)
    if cfg.family == "hybrid":
        changes.update(n_layers=5, attn_period=3, window=16, lru_width=64)
    if cfg.family == "ssm":
        changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16,
                       n_heads=1, n_kv_heads=1)
    if cfg.family == "audio":
        changes.update(n_enc_layers=2, n_frames=24)
    if cfg.family == "vlm":
        changes.update(cross_period=5, n_layers=5, n_vision_tokens=16)
    return dataclasses.replace(cfg, **changes)
