"""Griffin / RecurrentGemma hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern is (recurrent, recurrent, local-attention) triples (Griffin's
2:1); 38 layers = 12 scanned triples + 2 unscanned tail recurrent layers.

RG-LRU (De et al. 2024):  r,i = sigma(W x + b);  a = exp(-c softplus(L) r)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
Training uses ``lax.associative_scan`` over the diagonal recurrence (O(log S)
depth — the TPU-native alternative to a CUDA linear-scan kernel); decode is a
single elementwise state update (state is O(1) — this is why the arch runs the
long_500k cell).  The local-attention KV cache is a ``window``-sized ring
buffer.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models.common import (apply_stack, cross_entropy_loss, embed,
                                 embedding_init, lecun_init, rmsnorm,
                                 rmsnorm_init)
from repro.parallel.sharding import constrain

Array = jax.Array
_C = 8.0  # RG-LRU decay sharpness constant


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _geglu_init(key, d: int, f: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": lecun_init(k1, (d, f)), "w_in": lecun_init(k2, (d, f)),
            "w_out": lecun_init(k3, (f, d), fan_in=f)}


def _geglu(p: dict, x: Array) -> Array:
    dt = x.dtype
    return (jax.nn.gelu(x @ p["w_gate"].astype(dt)) * (x @ p["w_in"].astype(dt))
            ) @ p["w_out"].astype(dt)


def _rec_block_init(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 8)
    return {
        "ln1": rmsnorm_init(d), "ln2": rmsnorm_init(d),
        "w_x": lecun_init(ks[0], (d, w)),
        "w_gate_branch": lecun_init(ks[1], (d, w)),
        "conv": {"w": lecun_init(ks[2], (cfg.conv_width, w), fan_in=cfg.conv_width),
                 "b": jnp.zeros((w,), jnp.float32)},
        "lru": {
            "alpha": jax.random.uniform(ks[3], (w,), jnp.float32, 0.7, 0.95),
            "in_gate": {"w": lecun_init(ks[4], (w, w)), "b": jnp.zeros((w,))},
            "rec_gate": {"w": lecun_init(ks[5], (w, w)), "b": jnp.zeros((w,))},
        },
        "lru_out": lecun_init(ks[6], (w, d), fan_in=w),
        "mlp": _geglu_init(ks[7], d, cfg.d_ff),
    }


def _attn_block_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    hq, hkv = cfg.padded_heads(run.tp)
    ka, km = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(ka, cfg.d_model, hq, hkv,
                                       cfg.resolved_head_dim),
            "mlp": _geglu_init(km, cfg.d_model, cfg.d_ff)}


def _causal_conv(p: dict, x: Array) -> Array:
    """Depthwise causal temporal conv, width cw. x: (B,S,W)."""
    cw = p["w"].shape[0]
    dt = x.dtype
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["w"][i].astype(dt)
              for i in range(cw))
    return out + p["b"].astype(dt)


def _lru_coeffs(p: dict, xc: Array) -> tuple[Array, Array]:
    """a_t and b_t of the diagonal recurrence h_t = a_t h_{t-1} + b_t."""
    dt32 = jnp.float32
    x32 = xc.astype(dt32)
    r = jax.nn.sigmoid(x32 @ p["rec_gate"]["w"] + p["rec_gate"]["b"])
    i = jax.nn.sigmoid(x32 @ p["in_gate"]["w"] + p["in_gate"]["b"])
    log_a = -_C * jax.nn.softplus(p["alpha"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * x32)
    return a, b


def _rec_block(p: dict, cfg: ModelConfig, x: Array) -> Array:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    dt = x.dtype
    gate = jax.nn.gelu(h @ p["w_gate_branch"].astype(dt))
    u = h @ p["w_x"].astype(dt)
    u = _causal_conv(p["conv"], u)
    a, b = _lru_coeffs(p["lru"], u)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (gate * hseq.astype(dt)) @ p["lru_out"].astype(dt)
    x = x + constrain(y, "act_btd")
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + constrain(_geglu(p["mlp"], h), "act_btd")


def _attn_block(p: dict, cfg: ModelConfig, run: RunConfig, x: Array,
                positions: Array) -> Array:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a = attn_mod.full_attention(p["attn"], h, positions=positions,
                                theta=cfg.rope_theta, causal=True,
                                window=cfg.window,
                                use_kernel=run.use_flash_kernel)
    x = x + constrain(a, "act_btd")
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + constrain(_geglu(p["mlp"], h), "act_btd")


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _n_triples_tail(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.attn_period  # 3: (rec, rec, attn)
    return cfg.n_layers // period, cfg.n_layers % period


def init_params(key, cfg: ModelConfig, run: RunConfig) -> dict:
    from repro.models.transformer import _stack_init
    n_triples, n_tail = _n_triples_tail(cfg)
    ke, ku, kt, kx = jax.random.split(key, 4)
    params = {"embed": embedding_init(ke, cfg.padded_vocab(run.tp), cfg.d_model),
              "final_norm": rmsnorm_init(cfg.d_model),
              "unembed": {"w": lecun_init(ku, (cfg.d_model,
                                               cfg.padded_vocab(run.tp)))}}
    params["triples"] = _stack_init(kt, n_triples, lambda k: {
        "rec1": _rec_block_init(jax.random.fold_in(k, 0), cfg),
        "rec2": _rec_block_init(jax.random.fold_in(k, 1), cfg),
        "attn_layer": _attn_block_init(jax.random.fold_in(k, 2), cfg, run),
    })
    if n_tail:
        params["tail"] = _stack_init(kx, n_tail,
                                     lambda k: _rec_block_init(k, cfg))
    return params


def forward(params: dict, cfg: ModelConfig, run: RunConfig, tokens: Array,
            vision_embeds=None, return_hidden: bool = False) -> Array:
    del vision_embeds
    b, s = tokens.shape
    dt = jnp.dtype(run.compute_dtype)
    x = embed(params["embed"], tokens).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, tp):
        h = _rec_block(tp["rec1"], cfg, carry)
        h = _rec_block(tp["rec2"], cfg, h)
        h = _attn_block(tp["attn_layer"], cfg, run, h, positions)
        return h, ()
    if run.remat:
        body = jax.checkpoint(body)
    x, _ = apply_stack(body, x, params["triples"], unroll=not run.scan_layers)
    if "tail" in params:
        def tail_body(carry, tp):
            return _rec_block(tp, cfg, carry), ()
        x, _ = apply_stack(tail_body, x, params["tail"],
                           unroll=not run.scan_layers)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return constrain(x, "act_btd")
    logits = x @ params["unembed"]["w"].astype(dt)
    if cfg.padded_vocab(run.tp) != cfg.vocab:
        logits = logits + jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                                    0.0, -1e30).astype(dt)
    return constrain(logits, "logits")


def train_loss(params, cfg, run, batch) -> Array:
    if run.ce_chunk:
        from repro.models.common import chunked_ce_loss
        x = forward(params, cfg, run, batch["tokens"], return_hidden=True)
        pv = cfg.padded_vocab(run.tp)
        return chunked_ce_loss(x, params["unembed"]["w"], batch["labels"],
                               cfg.vocab, run.ce_chunk,
                               logit_mask_from=cfg.vocab if pv != cfg.vocab
                               else 0, unroll=not run.scan_layers)
    logits = forward(params, cfg, run, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], cfg.vocab)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class RecState(NamedTuple):
    conv_buf: Array    # (B, conv_width-1, lru_width) last inputs
    h: Array           # (B, lru_width)


class DecodeState(NamedTuple):
    triples: Any       # stacked {rec1, rec2: RecState, attn: KVCache}
    tail: Any
    pos: Array


def _zero_rec_state(cfg: ModelConfig, batch: int, dt) -> RecState:
    return RecState(conv_buf=jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dt),
                    h=jnp.zeros((batch, cfg.lru_width), jnp.float32))


def init_decode_state(params, cfg: ModelConfig, run: RunConfig, batch: int,
                      max_len: int, vision_embeds=None) -> DecodeState:
    del vision_embeds
    n_triples, n_tail = _n_triples_tail(cfg)
    dt = jnp.dtype(run.compute_dtype)
    hq, hkv = cfg.padded_heads(run.tp)
    rec = _zero_rec_state(cfg, batch, dt)
    cache = attn_mod.KVCache.zeros(batch, max_len, hkv, cfg.resolved_head_dim,
                                   dt, window=cfg.window)
    triple = {"rec1": rec, "rec2": rec, "attn": cache}
    triples = jax.tree.map(lambda x: jnp.broadcast_to(
        x, (n_triples,) + x.shape).copy() if hasattr(x, "shape") else x, triple)
    tail = jax.tree.map(lambda x: jnp.broadcast_to(
        x, (n_tail,) + x.shape).copy(), rec) if n_tail else None
    return DecodeState(triples=triples, tail=tail, pos=jnp.zeros((), jnp.int32))


def _rec_decode(p: dict, cfg: ModelConfig, x: Array, st: RecState
                ) -> tuple[Array, RecState]:
    """x: (B,1,D)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    dt = x.dtype
    gate = jax.nn.gelu(h @ p["w_gate_branch"].astype(dt))
    u = (h @ p["w_x"].astype(dt))[:, 0]                      # (B, W)
    # conv over [buf, u]
    hist = jnp.concatenate([st.conv_buf, u[:, None]], axis=1)  # (B, cw, W)
    cw = p["conv"]["w"].shape[0]
    uc = sum(hist[:, i] * p["conv"]["w"][i].astype(dt) for i in range(cw)) \
        + p["conv"]["b"].astype(dt)
    a, bcoef = _lru_coeffs(p["lru"], uc)
    hnew = a * st.h + bcoef
    y = (gate[:, 0] * hnew.astype(dt)) @ p["lru_out"].astype(dt)
    x = x + y[:, None]
    z = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + _geglu(p["mlp"], z)
    return x, RecState(conv_buf=hist[:, 1:], h=hnew)


def decode_step(params, cfg: ModelConfig, run: RunConfig, token: Array,
                state: DecodeState) -> tuple[Array, DecodeState]:
    dt = jnp.dtype(run.compute_dtype)
    x = embed(params["embed"], token).astype(dt)

    def body(h, scanned):
        tp, st = scanned
        h, s1 = _rec_decode(tp["rec1"], cfg, h, st["rec1"])
        h, s2 = _rec_decode(tp["rec2"], cfg, h, st["rec2"])
        z = rmsnorm(tp["attn_layer"]["ln1"], h, cfg.norm_eps)
        a, c2 = attn_mod.decode_attention(tp["attn_layer"]["attn"], z, st["attn"],
                                          theta=cfg.rope_theta)
        h = h + a
        z = rmsnorm(tp["attn_layer"]["ln2"], h, cfg.norm_eps)
        h = h + _geglu(tp["attn_layer"]["mlp"], z)
        return h, {"rec1": s1, "rec2": s2, "attn": c2}

    x, new_triples = apply_stack(body, x, (params["triples"], state.triples),
                                 unroll=not run.scan_layers)
    new_tail = state.tail
    if "tail" in params:
        def tail_body(h, scanned):
            tp, st = scanned
            return _rec_decode(tp, cfg, h, st)
        x, new_tail = apply_stack(tail_body, x, (params["tail"], state.tail),
                                  unroll=not run.scan_layers)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["unembed"]["w"].astype(dt)
    return logits, DecodeState(triples=new_triples, tail=new_tail,
                               pos=state.pos + 1)
