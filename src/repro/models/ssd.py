"""Mamba-2 (SSD — state-space duality) blocks, attention-free.

The SSD layer computes  y_s = sum_{t<=s} C_s^T B_t (dt_t x_t) exp(cum_s-cum_t)
with per-head scalar decay A.  Training/prefill uses the chunked form (paper
arXiv:2405.21060): quadratic attention-like math inside chunks of length Q,
plus an O(S/Q) inter-chunk state recurrence — exactly the structure a TPU
likes (chunk-local matmuls on the MXU + a short scan).  ``ssd_ref`` here is
the pure-jnp oracle; the Pallas kernel in ``repro.kernels.ssd_scan`` fuses the
chunk-local part with the state passing (grid iterated sequentially over
chunks, state carried in VMEM scratch).

Decode is a single state update: h = exp(A dt) h + B (dt x); y = C.h + D x —
O(1) per token, which is why mamba2 runs the long_500k cell.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.common import (apply_stack, cross_entropy_loss, embed,
                                 embedding_init, lecun_init, rmsnorm,
                                 rmsnorm_init)
from repro.parallel.sharding import constrain

Array = jax.Array


def _dims(cfg: ModelConfig) -> dict:
    d_inner = cfg.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return {"d_inner": d_inner, "H": n_heads, "P": cfg.ssm_headdim,
            "N": cfg.ssm_state, "G": cfg.ssm_ngroups,
            "conv_ch": d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state}


# ---------------------------------------------------------------------------
# SSD core (reference, chunked)
# ---------------------------------------------------------------------------

def ssd_ref(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
            chunk: int, h0: Array | None = None) -> tuple[Array, Array]:
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  a_log: (H,) [A = -exp(a_log)]
    b, c: (B,S,G,N) with G groups broadcast over heads.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s_in, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    chunk = min(chunk, s_in)
    pad = -s_in % chunk
    if pad:  # dt = 0 padding is an exact no-op (decay exp(0)=1, input x*0)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_in + pad
    nc = s // chunk
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,) negative
    dt32 = dt.astype(jnp.float32)
    xdt = (x.astype(jnp.float32) * dt32[..., None])          # B x_t dt_t term
    l = dt32 * a                                             # (B,S,H) log-decay
    lc = l.reshape(bsz, nc, chunk, h)
    cum = jnp.cumsum(lc, axis=2)                             # (B,nc,Q,H)
    xc = xdt.reshape(bsz, nc, chunk, h, p)
    bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(jnp.float32)

    # intra-chunk (quadratic within chunk)
    # decay(s,t) = exp(cum_s - cum_t) for t <= s
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Q,Q,H)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcshn,bcthn->bcsth", cc, bc) * decay.transpose(0, 1, 2, 3, 4)
    y = jnp.einsum("bcsth,bcthp->bcshp", scores, xc)

    # chunk boundary states: sum_t exp(cum_Q - cum_t) B_t xdt_t -> (B,nc,H,P,N)
    edge = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,Q,H)
    cstate = jnp.einsum("bcth,bcthn,bcthp->bchpn", edge, bc, xc)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    def scan_fn(carry, inp):
        cs, cd = inp
        new = carry * cd[:, :, None, None] + cs
        return new, carry                                     # emit INCOMING state

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    final, h_in = jax.lax.scan(scan_fn,
                               init,
                               (cstate.transpose(1, 0, 2, 3, 4),
                                chunk_decay.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                     # (B,nc,H,P,N)

    # inter-chunk contribution: C_s exp(cum_s) h_in
    y_inter = jnp.einsum("bcsh,bcshn,bchpn->bcshp", jnp.exp(cum), cc, h_in)
    y = (y + y_inter).reshape(bsz, s, h, p)[:, :s_in]
    return y.astype(x.dtype), final


def ssd_decode_step(h: Array, x: Array, dt: Array, a_log: Array, b: Array,
                    c: Array) -> tuple[Array, Array]:
    """One-token update. h: (B,H,P,N); x: (B,H,P); dt: (B,H); b,c: (B,G,N)."""
    g = b.shape[1]
    rep = h.shape[1] // g
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=1)       # (B,H,N)
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=1)
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a)               # (B,H)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    h_new = h * decay[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xdt, bf)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, cf)
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig) -> dict:
    dm = _dims(cfg)
    ks = jax.random.split(key, 5)
    proj_out = dm["d_inner"] * 2 + 2 * dm["G"] * dm["N"] + dm["H"]
    return {"ln": rmsnorm_init(cfg.d_model),
            "ssd": {
                "in_proj": lecun_init(ks[0], (cfg.d_model, proj_out)),
                "conv_w": lecun_init(ks[1], (cfg.conv_width, dm["conv_ch"]),
                                     fan_in=cfg.conv_width),
                "conv_b": jnp.zeros((dm["conv_ch"],), jnp.float32),
                "A_log": jnp.log(jax.random.uniform(ks[2], (dm["H"],),
                                                    jnp.float32, 1.0, 16.0)),
                "dt_bias": jnp.log(jnp.expm1(jax.random.uniform(
                    ks[3], (dm["H"],), jnp.float32, 1e-3, 1e-1))),
                "D": jnp.ones((dm["H"],), jnp.float32),
                "norm_scale": jnp.ones((dm["d_inner"],), jnp.float32),
                "out_proj": lecun_init(ks[4], (dm["d_inner"], cfg.d_model),
                                       fan_in=dm["d_inner"]),
            }}


def _split_proj(cfg: ModelConfig, proj: Array):
    dm = _dims(cfg)
    di, gn, h = dm["d_inner"], dm["G"] * dm["N"], dm["H"]
    z = proj[..., :di]
    xin = proj[..., di:2 * di]
    b = proj[..., 2 * di:2 * di + gn]
    c = proj[..., 2 * di + gn:2 * di + 2 * gn]
    dt = proj[..., 2 * di + 2 * gn:]
    return z, xin, b, c, dt


def _block_forward(p: dict, cfg: ModelConfig, run: RunConfig, x: Array,
                   use_kernel: bool) -> Array:
    dm = _dims(cfg)
    dt_ = x.dtype
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = h @ p["ssd"]["in_proj"].astype(dt_)
    z, xin, b, c, dtp = _split_proj(cfg, proj)
    # causal conv + silu over [x, B, C]
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    cw = cfg.conv_width
    padded = jnp.pad(conv_in, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(padded[:, i:i + x.shape[1]] * p["ssd"]["conv_w"][i].astype(dt_)
               for i in range(cw)) + p["ssd"]["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv)
    di, gn = dm["d_inner"], dm["G"] * dm["N"]
    xs = conv[..., :di].reshape(x.shape[0], x.shape[1], dm["H"], dm["P"])
    bs = conv[..., di:di + gn].reshape(x.shape[0], x.shape[1], dm["G"], dm["N"])
    cs = conv[..., di + gn:].reshape(x.shape[0], x.shape[1], dm["G"], dm["N"])
    dt_act = jax.nn.softplus(dtp.astype(jnp.float32) + p["ssd"]["dt_bias"])
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd(xs, dt_act, p["ssd"]["A_log"], bs, cs,
                           chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_ref(xs, dt_act, p["ssd"]["A_log"], bs, cs, chunk=cfg.ssm_chunk)
    y = y + xs * p["ssd"]["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = rmsnorm({"scale": p["ssd"]["norm_scale"]}, y * jax.nn.silu(z),
                cfg.norm_eps)
    return x + constrain(y @ p["ssd"]["out_proj"].astype(dt_), "act_btd")


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, run: RunConfig) -> dict:
    from repro.models.transformer import _stack_init
    ke, ku, kl = jax.random.split(key, 3)
    return {"embed": embedding_init(ke, cfg.padded_vocab(run.tp), cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
            "unembed": {"w": lecun_init(ku, (cfg.d_model,
                                             cfg.padded_vocab(run.tp)))},
            "layers": _stack_init(kl, cfg.n_layers,
                                  lambda k: _block_init(k, cfg))}


def forward(params, cfg: ModelConfig, run: RunConfig, tokens: Array,
            vision_embeds=None, return_hidden: bool = False) -> Array:
    del vision_embeds
    dt = jnp.dtype(run.compute_dtype)
    x = embed(params["embed"], tokens).astype(dt)

    def body(carry, lp):
        return _block_forward(lp, cfg, run, carry, run.use_flash_kernel), ()
    if run.remat:
        body = jax.checkpoint(body)
    x, _ = apply_stack(body, x, params["layers"], unroll=not run.scan_layers)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return constrain(x, "act_btd")
    logits = x @ params["unembed"]["w"].astype(dt)
    if cfg.padded_vocab(run.tp) != cfg.vocab:
        logits = logits + jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                                    0.0, -1e30).astype(dt)
    return constrain(logits, "logits")


def train_loss(params, cfg, run, batch) -> Array:
    if run.ce_chunk:
        from repro.models.common import chunked_ce_loss
        x = forward(params, cfg, run, batch["tokens"], return_hidden=True)
        pv = cfg.padded_vocab(run.tp)
        return chunked_ce_loss(x, params["unembed"]["w"], batch["labels"],
                               cfg.vocab, run.ce_chunk,
                               logit_mask_from=cfg.vocab if pv != cfg.vocab
                               else 0, unroll=not run.scan_layers)
    logits = forward(params, cfg, run, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"], cfg.vocab)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class SsdState(NamedTuple):
    conv_buf: Array    # (B, cw-1, conv_ch)
    h: Array           # (B, H, P, N) fp32


class DecodeState(NamedTuple):
    layers: Any
    pos: Array


def init_decode_state(params, cfg: ModelConfig, run: RunConfig, batch: int,
                      max_len: int, vision_embeds=None) -> DecodeState:
    del vision_embeds, max_len
    dm = _dims(cfg)
    dt = jnp.dtype(run.compute_dtype)
    st = SsdState(conv_buf=jnp.zeros((batch, cfg.conv_width - 1, dm["conv_ch"]), dt),
                  h=jnp.zeros((batch, dm["H"], dm["P"], dm["N"]), jnp.float32))
    layers = jax.tree.map(lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
                          st)
    return DecodeState(layers=layers, pos=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ModelConfig, run: RunConfig, token: Array,
                state: DecodeState) -> tuple[Array, DecodeState]:
    dm = _dims(cfg)
    dt = jnp.dtype(run.compute_dtype)
    x = embed(params["embed"], token).astype(dt)

    def body(h, scanned):
        lp, st = scanned
        z0 = rmsnorm(lp["ln"], h, cfg.norm_eps)
        proj = z0 @ lp["ssd"]["in_proj"].astype(dt)
        z, xin, b, c, dtp = _split_proj(cfg, proj)
        conv_in = jnp.concatenate([xin, b, c], axis=-1)[:, 0]   # (B, conv_ch)
        hist = jnp.concatenate([st.conv_buf, conv_in[:, None]], axis=1)
        cw = cfg.conv_width
        conv = sum(hist[:, i] * lp["ssd"]["conv_w"][i].astype(dt)
                   for i in range(cw)) + lp["ssd"]["conv_b"].astype(dt)
        conv = jax.nn.silu(conv)
        di, gn = dm["d_inner"], dm["G"] * dm["N"]
        xs = conv[:, :di].reshape(-1, dm["H"], dm["P"])
        bs = conv[:, di:di + gn].reshape(-1, dm["G"], dm["N"])
        cs = conv[:, di + gn:].reshape(-1, dm["G"], dm["N"])
        dt_act = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + lp["ssd"]["dt_bias"])
        y, h_new = ssd_decode_step(st.h, xs, dt_act, lp["ssd"]["A_log"], bs, cs)
        y = y + xs * lp["ssd"]["D"].astype(dt)[None, :, None]
        y = y.reshape(-1, 1, di)
        y = rmsnorm({"scale": lp["ssd"]["norm_scale"]}, y * jax.nn.silu(z),
                    cfg.norm_eps)
        out = h + y @ lp["ssd"]["out_proj"].astype(dt)
        return out, SsdState(conv_buf=hist[:, 1:], h=h_new)

    x, new_layers = apply_stack(body, x, (params["layers"], state.layers),
                                unroll=not run.scan_layers)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["unembed"]["w"].astype(dt)
    return logits, DecodeState(layers=new_layers, pos=state.pos + 1)
