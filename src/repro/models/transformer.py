"""Decoder-only transformer backbone: dense (qwen/deepseek/llama), MoE
(llama4-scout, granite) and VLM (llama-3.2-vision, cross-attention every Nth
layer) families.

Layer stacks are *scanned* (`jax.lax.scan` over stacked params), keeping HLO
size O(1) in depth — essential for 62-layer models compiled for 512-way SPMD.
For the VLM family the scan unit is a group of ``cross_period`` layers with the
cross-attention layer at in-group index ``cross_period - 2`` (llama-3.2's
cross layers sit at 3, 8, 13, ... = groups of 5 with cross at local index 3).

Decode caches are stacked along the same leading layer axis so the decode step
scans (layer_params, layer_cache) jointly.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.common import (apply_stack, cross_entropy_loss, embed,
                                 embedding_init, lecun_init, rmsnorm,
                                 rmsnorm_init, swiglu, swiglu_init, unembed,
                                 unembed_init)
from repro.parallel.sharding import constrain

Array = jax.Array


def _stack_init(key, n: int, init_fn) -> Any:
    """Initialize n layers and stack leaves along a new leading axis."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    hq, hkv = cfg.padded_heads(run.tp)
    hd = cfg.resolved_head_dim
    ka, km = jax.random.split(key)
    p = {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model),
         "attn": attn_mod.attn_init(ka, cfg.d_model, hq, hkv, hd,
                                    qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(km, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                    shared_expert=cfg.shared_expert)
    else:
        p["mlp"] = swiglu_init(km, cfg.d_model, cfg.d_ff)
    return p


def _cross_layer_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    p = _layer_init(key, cfg, run)
    p["gate_attn"] = jnp.zeros((), jnp.float32)   # llama-3.2 tanh gates
    p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def _apply_ffn(p: dict, cfg: ModelConfig, run: RunConfig, h: Array) -> Array:
    if cfg.family == "moe":
        return moe_mod.moe_apply(p["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 dispatch_groups=run.moe_dispatch_groups)
    return swiglu(p["mlp"], h)


def _self_layer(p: dict, cfg: ModelConfig, run: RunConfig, x: Array,
                positions: Array, window: int = 0) -> Array:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a = attn_mod.full_attention(p["attn"], h, positions=positions,
                                theta=cfg.rope_theta, causal=True,
                                window=window, use_kernel=run.use_flash_kernel)
    x = x + constrain(a, "act_btd")
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + constrain(_apply_ffn(p, cfg, run, h), "act_btd")
    return x


def _cross_layer(p: dict, cfg: ModelConfig, run: RunConfig, x: Array,
                 vision: Array) -> Array:
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    dummy_pos = jnp.zeros((x.shape[0], x.shape[1]), jnp.int32)
    a = attn_mod.full_attention(p["attn"], h, positions=dummy_pos,
                                theta=cfg.rope_theta, x_kv=vision)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * constrain(a, "act_btd")
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * \
        constrain(_apply_ffn(p, cfg, run, h), "act_btd")
    return x


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, run: RunConfig) -> dict:
    ke, ku, kl, kx = jax.random.split(key, 4)
    vocab = cfg.padded_vocab(run.tp)
    params = {"embed": embedding_init(ke, vocab, cfg.d_model),
              "final_norm": rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["unembed"] = unembed_init(ku, cfg.d_model, vocab)
    if cfg.family == "vlm":
        period = cfg.cross_period
        n_groups = cfg.n_layers // period
        params["groups"] = _stack_init(kl, n_groups, lambda k: {
            "selfs": _stack_init(k, period - 1,
                                 lambda kk: _layer_init(kk, cfg, run)),
            "cross": _cross_layer_init(jax.random.fold_in(k, 7), cfg, run),
        })
        params["vision_proj"] = {"w": lecun_init(kx, (cfg.d_model, cfg.d_model))}
    else:
        params["layers"] = _stack_init(kl, cfg.n_layers,
                                       lambda k: _layer_init(k, cfg, run))
    return params


def cast_params(params, dtype) -> dict:
    return jax.tree.map(lambda x: x.astype(dtype)
                        if isinstance(x, jax.Array) and
                        jnp.issubdtype(x.dtype, jnp.floating) else x, params)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: dict, cfg: ModelConfig, run: RunConfig, tokens: Array,
            vision_embeds: Optional[Array] = None,
            return_hidden: bool = False) -> Array:
    """tokens (B, S) -> logits (B, S, padded_vocab); with ``return_hidden``
    the final normed hidden states (B, S, D) instead (chunked-CE path)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens).astype(_dt(run))
    x = constrain(x, "act_btd")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "vlm":
        vision = (vision_embeds.astype(_dt(run)) @
                  params["vision_proj"]["w"].astype(_dt(run)))

        def group_body(carry, gp):
            h = carry

            def self_body(hh, lp):
                out = _self_layer(lp, cfg, run, hh, positions)
                return out, ()
            if run.remat:
                self_body = jax.checkpoint(self_body)
            h, _ = apply_stack(self_body, h, gp["selfs"],
                               unroll=not run.scan_layers)
            h = _cross_layer(gp["cross"], cfg, run, h, vision)
            return h, ()

        x, _ = apply_stack(group_body, x, params["groups"],
                           unroll=not run.scan_layers)
    else:
        def body(carry, lp):
            return _self_layer(lp, cfg, run, carry, positions), ()
        if run.remat:
            body = jax.checkpoint(body)
        x, _ = apply_stack(body, x, params["layers"],
                           unroll=not run.scan_layers)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return constrain(x, "act_btd")
    logits = _lm_head(params, cfg, run, x)
    return constrain(logits, "logits")


def _lm_head(params: dict, cfg: ModelConfig, run: RunConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["w"].astype(x.dtype).T
    else:
        logits = unembed(params["unembed"], x)
    pv = cfg.padded_vocab(run.tp)
    if pv != cfg.vocab:
        # physical vocab padding (DESIGN.md §5): dead columns masked to -inf
        mask = jnp.where(jnp.arange(pv) < cfg.vocab, 0.0, -1e30)
        logits = logits + mask.astype(logits.dtype)
    return logits


def train_loss(params: dict, cfg: ModelConfig, run: RunConfig, batch: dict) -> Array:
    if run.ce_chunk:
        from repro.models.common import chunked_ce_loss
        x = forward(params, cfg, run, batch["tokens"],
                    vision_embeds=batch.get("vision_embeds"),
                    return_hidden=True)
        w = (params["embed"]["w"].T if cfg.tie_embeddings
             else params["unembed"]["w"])
        pv = cfg.padded_vocab(run.tp)
        return chunked_ce_loss(x, w, batch["labels"], cfg.vocab,
                               run.ce_chunk,
                               logit_mask_from=cfg.vocab if pv != cfg.vocab
                               else 0,
                               unroll=not run.scan_layers)
    logits = forward(params, cfg, run, batch["tokens"],
                     vision_embeds=batch.get("vision_embeds"))
    return cross_entropy_loss(logits, batch["labels"], cfg.vocab)


def _dt(run: RunConfig):
    return jnp.dtype(run.compute_dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any            # stacked KVCache (leading layer/group axis)
    vision_kv: Any         # vlm only: stacked cross K/V per group
    pos: Array


def init_decode_state(params: dict, cfg: ModelConfig, run: RunConfig,
                      batch: int, max_len: int,
                      vision_embeds: Optional[Array] = None) -> DecodeState:
    hq, hkv = cfg.padded_heads(run.tp)
    hd = cfg.resolved_head_dim
    dt = _dt(run)

    proto = attn_mod.KVCache.zeros(batch, max_len, hkv, hd, dt, window=0)

    if cfg.family == "vlm":
        n_groups = cfg.n_layers // cfg.cross_period
        caches = {"selfs": jax.tree.map(
            lambda x: jnp.zeros((n_groups, cfg.cross_period - 1) + x.shape,
                                x.dtype), proto)}
        vision = (vision_embeds.astype(dt) @ params["vision_proj"]["w"].astype(dt))

        def cross_kv(gp):
            _, k, v = attn_mod._project_qkv(gp["cross"]["attn"], vision, vision,
                                            jnp.zeros(vision.shape[:2], jnp.int32),
                                            cfg.rope_theta, rope=False)
            return k, v
        vision_kv = jax.vmap(cross_kv)(params["groups"])
        return DecodeState(caches=caches, vision_kv=vision_kv,
                           pos=jnp.zeros((), jnp.int32))

    caches = jax.tree.map(lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
                          proto)
    return DecodeState(caches=caches, vision_kv=None, pos=jnp.zeros((), jnp.int32))


def decode_step(params: dict, cfg: ModelConfig, run: RunConfig, token: Array,
                state: DecodeState) -> tuple[Array, DecodeState]:
    """token (B, 1) int32 -> (logits (B, 1, V), new state)."""
    x = embed(params["embed"], token).astype(_dt(run))

    def self_decode(p, c, h):
        z = rmsnorm(p["ln1"], h, cfg.norm_eps)
        a, c2 = attn_mod.decode_attention(p["attn"], z, c, theta=cfg.rope_theta)
        h = h + a
        z = rmsnorm(p["ln2"], h, cfg.norm_eps)
        return h + _apply_ffn(p, cfg, run, z), c2

    if cfg.family == "vlm":
        def group_body(h, scanned):
            gp, gc, vkv = scanned

            def inner(hh, lp_c):
                out, c2 = self_decode(lp_c[0], lp_c[1], hh)
                return out, c2
            h, new_self = apply_stack(inner, h, (gp["selfs"], gc),
                                      unroll=not run.scan_layers)
            # cross layer (cache-free)
            p = gp["cross"]
            z = rmsnorm(p["ln1"], h, cfg.norm_eps)
            a, _ = attn_mod.decode_attention(p["attn"], z, _dummy_cache(h, cfg, run),
                                             theta=cfg.rope_theta, kv_cross=vkv)
            h = h + jnp.tanh(p["gate_attn"]).astype(h.dtype) * a
            z = rmsnorm(p["ln2"], h, cfg.norm_eps)
            h = h + jnp.tanh(p["gate_mlp"]).astype(h.dtype) * \
                _apply_ffn(p, cfg, run, z)
            return h, new_self

        x, new_selfs = apply_stack(group_body, x,
                                   (params["groups"], state.caches["selfs"],
                                    state.vision_kv),
                                   unroll=not run.scan_layers)
        new_caches = {"selfs": new_selfs}
    else:
        def body(h, scanned):
            lp, c = scanned
            out, c2 = self_decode(lp, c, h)
            return out, c2
        x, new_caches = apply_stack(body, x, (params["layers"], state.caches),
                                    unroll=not run.scan_layers)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_head(params, cfg, run, x)
    return logits, DecodeState(caches=new_caches, vision_kv=state.vision_kv,
                               pos=state.pos + 1)


def _dummy_cache(x: Array, cfg: ModelConfig, run: RunConfig):
    hq, hkv = cfg.padded_heads(run.tp)
    return attn_mod.KVCache.zeros(x.shape[0], 1, hkv, cfg.resolved_head_dim,
                                  x.dtype)
