"""Whisper-style encoder-decoder backbone (conv/mel frontend is a STUB).

Per the assignment, the modality frontend is not modeled: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d_model) that stand in for
the output of whisper's two conv layers over the mel spectrogram.  Everything
after that is faithful: sinusoidal encoder positions, pre-LN transformer
encoder (bidirectional), decoder with learned positions, causal self-attention
+ cross-attention, GELU MLPs, tied unembedding.

24 "layers" per the assigned config are interpreted as whisper-medium's
24 encoder + 24 decoder layers.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models.common import (apply_stack, cross_entropy_loss, embed,
                                 embedding_init, gelu_mlp, gelu_mlp_init,
                                 layernorm, layernorm_init, sincos_positions)
from repro.parallel.sharding import constrain

Array = jax.Array


def _enc_layer_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    hq, hkv = cfg.padded_heads(run.tp)
    ka, km = jax.random.split(key)
    return {"ln1": layernorm_init(cfg.d_model), "ln2": layernorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(ka, cfg.d_model, hq, hkv,
                                       cfg.resolved_head_dim, qkv_bias=True),
            "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff)}


def _dec_layer_init(key, cfg: ModelConfig, run: RunConfig) -> dict:
    hq, hkv = cfg.padded_heads(run.tp)
    ka, kx, km = jax.random.split(key, 3)
    return {"ln1": layernorm_init(cfg.d_model), "ln2": layernorm_init(cfg.d_model),
            "ln3": layernorm_init(cfg.d_model),
            "attn": attn_mod.attn_init(ka, cfg.d_model, hq, hkv,
                                       cfg.resolved_head_dim, qkv_bias=True),
            "xattn": attn_mod.attn_init(kx, cfg.d_model, hq, hkv,
                                        cfg.resolved_head_dim, qkv_bias=True),
            "mlp": gelu_mlp_init(km, cfg.d_model, cfg.d_ff)}


def init_params(key, cfg: ModelConfig, run: RunConfig) -> dict:
    from repro.models.transformer import _stack_init
    ke, kd, kp, kt = jax.random.split(key, 4)
    return {
        "embed": embedding_init(kt, cfg.padded_vocab(run.tp), cfg.d_model),
        "pos_embed": {"w": jax.random.normal(kp, (4096 if cfg.max_seq > 4096
                                                  else cfg.max_seq, cfg.d_model),
                                             jnp.float32) * 0.02},
        "enc_layers": _stack_init(ke, cfg.n_enc_layers,
                                  lambda k: _enc_layer_init(k, cfg, run)),
        "dec_layers": _stack_init(kd, cfg.n_layers,
                                  lambda k: _dec_layer_init(k, cfg, run)),
        "enc_final_ln": layernorm_init(cfg.d_model),
        "dec_final_ln": layernorm_init(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, run: RunConfig, frames: Array) -> Array:
    """frames: (B, F, D) precomputed frame embeddings (frontend stub)."""
    dt = jnp.dtype(run.compute_dtype)
    x = frames.astype(dt) + sincos_positions(frames.shape[1],
                                             cfg.d_model).astype(dt)[None]
    dummy_pos = jnp.zeros(frames.shape[:2], jnp.int32)

    def body(carry, lp):
        h = layernorm(lp["ln1"], carry)
        a = attn_mod.full_attention(lp["attn"], h, positions=dummy_pos,
                                    causal=False, rope=False)
        carry = carry + constrain(a, "act_btd")
        h = layernorm(lp["ln2"], carry)
        return carry + constrain(gelu_mlp(lp["mlp"], h), "act_btd"), ()
    if run.remat:
        body = jax.checkpoint(body)
    x, _ = apply_stack(body, x, params["enc_layers"],
                       unroll=not run.scan_layers)
    return layernorm(params["enc_final_ln"], x)


def _dec_layer(lp, cfg, run, x, positions, enc_out):
    h = layernorm(lp["ln1"], x)
    a = attn_mod.full_attention(lp["attn"], h, positions=positions,
                                causal=True, rope=False,
                                use_kernel=run.use_flash_kernel)
    x = x + constrain(a, "act_btd")
    h = layernorm(lp["ln2"], x)
    a = attn_mod.full_attention(lp["xattn"], h, positions=positions,
                                x_kv=enc_out, rope=False)
    x = x + constrain(a, "act_btd")
    h = layernorm(lp["ln3"], x)
    return x + constrain(gelu_mlp(lp["mlp"], h), "act_btd")


def forward(params, cfg: ModelConfig, run: RunConfig, tokens: Array,
            frames: Array) -> Array:
    """Teacher-forced decoder logits."""
    dt = jnp.dtype(run.compute_dtype)
    enc_out = encode(params, cfg, run, frames)
    b, s = tokens.shape
    pos_table = params["pos_embed"]["w"]
    # decoder longer than the learned table tiles the table (dry-run shapes
    # exceed whisper's 448-ctx design; documented in DESIGN.md §5)
    pos = jnp.arange(s) % pos_table.shape[0]
    x = embed(params["embed"], tokens).astype(dt) + pos_table[pos].astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        return _dec_layer(lp, cfg, run, carry, positions, enc_out), ()
    if run.remat:
        body = jax.checkpoint(body)
    x, _ = apply_stack(body, x, params["dec_layers"],
                       unroll=not run.scan_layers)
    x = layernorm(params["dec_final_ln"], x)
    logits = x @ params["embed"]["w"].astype(dt).T
    if cfg.padded_vocab(run.tp) != cfg.vocab:
        logits = logits + jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                                    0.0, -1e30).astype(dt)
    return constrain(logits, "logits")


def train_loss(params, cfg, run, batch) -> Array:
    logits = forward(params, cfg, run, batch["tokens"], batch["frames"])
    return cross_entropy_loss(logits, batch["labels"], cfg.vocab)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any        # stacked self-attn KVCache per decoder layer
    cross_kv: Any      # stacked (k, v) per decoder layer from the encoder
    pos: Array


def init_decode_state(params, cfg: ModelConfig, run: RunConfig, batch: int,
                      max_len: int, frames: Array) -> DecodeState:
    dt = jnp.dtype(run.compute_dtype)
    hq, hkv = cfg.padded_heads(run.tp)
    enc_out = encode(params, cfg, run, frames)

    def cross_kv(lp):
        _, k, v = attn_mod._project_qkv(lp["xattn"], enc_out, enc_out,
                                        jnp.zeros(enc_out.shape[:2], jnp.int32),
                                        0.0, rope=False)
        return k, v
    ckv = jax.vmap(cross_kv)(params["dec_layers"])
    proto = attn_mod.KVCache.zeros(batch, max_len, hkv, cfg.resolved_head_dim, dt)
    caches = jax.tree.map(lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
                          proto)
    return DecodeState(caches=caches, cross_kv=ckv, pos=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ModelConfig, run: RunConfig, token: Array,
                state: DecodeState) -> tuple[Array, DecodeState]:
    dt = jnp.dtype(run.compute_dtype)
    pos_table = params["pos_embed"]["w"]
    x = embed(params["embed"], token).astype(dt) + \
        pos_table[state.pos % pos_table.shape[0]].astype(dt)[None, None]

    def body(h, scanned):
        lp, c, ckv = scanned
        z = layernorm(lp["ln1"], h)
        a, c2 = attn_mod.decode_attention(lp["attn"], z, c, rope=False)
        h = h + a
        z = layernorm(lp["ln2"], h)
        a, _ = attn_mod.decode_attention(lp["xattn"], z, c2, rope=False,
                                         kv_cross=ckv)
        h = h + a
        z = layernorm(lp["ln3"], h)
        return h + gelu_mlp(lp["mlp"], z), c2

    x, new_caches = apply_stack(body, x, (params["dec_layers"], state.caches,
                                          state.cross_kv),
                                unroll=not run.scan_layers)
    x = layernorm(params["dec_final_ln"], x)
    logits = x @ params["embed"]["w"].astype(dt).T
    return logits, DecodeState(caches=new_caches, cross_kv=state.cross_kv,
                               pos=state.pos + 1)
