"""Collective accounting: parse lowered/compiled HLO text and sum the operand
bytes of every communication op — the ``collective term`` input of the
roofline analysis (cost_analysis() does not expose collective bytes).

Also provides the distributed-PCA covariance reduction used by GAE at scale
(DESIGN.md §4.5): a D x D psum, communication independent of dataset size.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Optional

import jax
import jax.numpy as jnp

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# e.g.  f32[256,1024]{1,0} or bf16[8,128] (layout braces optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape-or-tuple> op-name(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind across an HLO module.

    Uses each op's RESULT shape (for all-reduce in == out; for all-gather the
    result is the global view = bytes that transited links under a ring; a
    standard, conservative convention for roofline purposes).  ``-done`` ops
    are skipped so async pairs are not double-counted.
    """
    out: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return {"bytes": dict(out), "counts": dict(counts),
            "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# distributed PCA covariance (GAE at scale)
# ---------------------------------------------------------------------------

def distributed_covariance(local_residuals: jax.Array,
                           axis_name: Optional[str] = None) -> jax.Array:
    """C = sum_i r_i r_i^T, psum'd over the data axis: O(D^2) communication,
    independent of the number of residual blocks."""
    r = local_residuals.astype(jnp.float32)
    cov = r.T @ r
    if axis_name is not None:
        cov = jax.lax.psum(cov, axis_name)
    return cov
