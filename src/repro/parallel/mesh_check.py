"""Multi-device parity selfcheck for the mesh-sharded stage pipeline.

Run as a SUBPROCESS (device count is frozen at first jax import, so a
pytest process that already initialized jax cannot host this check)::

    python -m repro.parallel.mesh_check            # 4 virtual CPU devices
    REPRO_MESH_CHECK_DEVICES=2 python -m repro.parallel.mesh_check

Asserts, on a small untrained compressor (random-init params, fitted PCA
basis — the same construction the unit tests use):

1. **batch parity** — ``compress(options=...mesh=N)`` serializes to the
   exact bytes of the single-device archive;
2. **stream parity** — ``stream_compress`` with a mesh produces the same
   bytes again, in memory AND on disk;
3. **zero retraces** — a second sharded+unsharded compress pass triggers no
   new traces (the mesh-keyed ``JitCache`` keeps both program sets live);
4. **psum basis** — the shard_map'd PCA fit matches the single-device basis
   to float32 tolerance (psum order may differ in the last ulp);
5. **sharded decompress** — the mesh decode back-end reproduces the
   single-device reconstruction within float32 tolerance and the tau
   guarantee holds on every GAE block;
6. **options shim** — the deprecated kwarg surface produces byte-identical
   archives to the ``CompressOptions`` surface and warns exactly once.

Prints one JSON report; exits nonzero if any check fails.  The smoke gate
(``scripts/smoke.sh``) and ``tests/test_mesh_exec.py`` both run this.
"""
from __future__ import annotations

import json
import os
import sys

DEVICES = int(os.environ.get("REPRO_MESH_CHECK_DEVICES", "4"))


def _force_devices(n: int) -> None:
    """Must run before the first jax import in this process."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            (flags + f" --xla_force_host_platform_device_count={n}").strip()


_force_devices(DEVICES)

import numpy as np                                          # noqa: E402

import jax                                                  # noqa: E402

from repro.core import CompressorConfig, HierarchicalCompressor  # noqa: E402
from repro.core import bae as bae_mod                       # noqa: E402
from repro.core import exec as exec_mod                     # noqa: E402
from repro.core import gae                                  # noqa: E402
from repro.core import hbae as hbae_mod                     # noqa: E402
from repro.core.options import CompressOptions              # noqa: E402
from repro.parallel import mesh_exec                        # noqa: E402
from repro.runtime import archive_io                        # noqa: E402
from repro.stream import stream_compress                    # noqa: E402

TAU = 0.5


def _make_comp(n_hb: int = 24) -> tuple[HierarchicalCompressor, np.ndarray]:
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32,
                           hb_latent=8, bae_hidden=32, bae_latent=4,
                           gae_block_elems=80, hb_bin=0.01, bae_bin=0.01,
                           gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(0))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(0)
    hb = 0.1 * rng.standard_normal(
        (n_hb, cfg.k, cfg.block_elems)).astype(np.float32)
    comp.fit_basis(hb)          # shared basis: parity is about the pipeline
    return comp, hb


def main() -> int:
    checks: list[dict] = []

    def check(name: str, ok: bool, detail: str = "") -> None:
        checks.append({"name": name, "ok": bool(ok), "detail": detail})

    n_dev = len(jax.devices())
    want = DEVICES
    if n_dev < max(2, want):
        print(json.dumps({
            "ok": False, "devices": n_dev,
            "error": f"need {want} devices, found {n_dev} — jax was "
                     f"imported before XLA_FLAGS took effect, or the "
                     f"platform refuses virtual devices"}))
        return 1

    comp, hb = _make_comp()
    # chunk width 4 over 24 hyper-blocks with 4 shards: one aligned group of
    # 4 stripes (the shard_map path) + a 2-stripe ragged tail (per-stripe
    # path) — both paths exercised in one archive
    base_opts = CompressOptions(tau=TAU, chunk_hyperblocks=4)
    mesh_opts = base_opts.replace(mesh=want)

    single = comp.compress(hb, options=base_opts)
    sharded = comp.compress(hb, options=mesh_opts)
    blob_single = archive_io.serialize_archive(single)
    blob_sharded = archive_io.serialize_archive(sharded)
    check("batch_parity", blob_sharded == blob_single,
          f"{len(blob_single)} bytes, {len(single.chunks)} chunks")

    cnt = exec_mod.counters()
    check("sharded_groups_ran", cnt.get("mesh.sharded_groups", 0) >= 1
          and cnt.get("mesh.shards", 0) == want,
          f"groups={cnt.get('mesh.sharded_groups', 0)} "
          f"shards={cnt.get('mesh.shards', 0)}")

    out = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                       f"mesh_check_{os.getpid()}.rba")
    try:
        result = stream_compress(comp, hb, options=mesh_opts, out_path=out)
        blob_stream = archive_io.serialize_archive(result.archive)
        with open(out, "rb") as f:
            disk = f.read()
        check("stream_parity",
              blob_stream == blob_single and disk == blob_single,
              f"stream items={result.stats.n_items} "
              f"chunks={len(result.archive.chunks)}")
    finally:
        for p in (out, out + ".partial"):
            if os.path.exists(p):
                os.unlink(p)

    before = exec_mod.total_retraces()
    comp.compress(hb, options=base_opts)
    comp.compress(hb, options=mesh_opts)
    delta = exec_mod.total_retraces() - before
    check("zero_retraces_after_warmup", delta == 0,
          f"delta={delta} counts={exec_mod.retrace_counts()}")

    # psum basis: needs a FULL-RANK covariance (rows >> dims) — on a
    # rank-deficient one the null-space eigenvectors are arbitrary and no
    # comparison is meaningful.  Column comparison is sign-invariant
    # (|u_i . v_i| ~ 1): eigh's per-column sign is a convention, not math.
    rng = np.random.default_rng(7)
    resid = rng.standard_normal((400, 80)).astype(np.float32) * 0.1
    basis_single = np.asarray(gae.fit_pca_basis(resid))
    mesh = mesh_exec.make_compress_mesh(want)
    basis_sharded = mesh_exec.fit_pca_basis_sharded(resid, mesh)
    align = np.abs(np.sum(basis_single * basis_sharded, axis=0))
    check("psum_basis_consistent",
          basis_sharded.shape == basis_single.shape
          and bool(np.all(align > 1 - 1e-3)),
          f"min |col alignment| = {float(align.min()):.6f}")

    # ...and the end-to-end property that actually matters: a basis fitted
    # THROUGH the mesh still drives a guarantee-satisfying compress
    comp2, hb2 = _make_comp()
    comp2.basis = None
    comp2.fit_basis(hb2, mesh=want)
    a2 = comp2.compress(hb2, options=base_opts)
    r2 = comp2.decompress(a2)
    d_gae = comp2.cfg.gae_block_elems or comp2.cfg.block_elems
    errs2 = np.linalg.norm((hb2 - r2).reshape(-1, d_gae), axis=1)
    check("sharded_basis_honors_tau",
          float(errs2.max()) <= TAU * (1 + 1e-5),
          f"max block l2 {float(errs2.max()):.4f} <= tau={TAU}")

    dec_single = comp.decompress(single)
    dec_sharded = comp.decompress(single, mesh=want)
    d_gae = comp.cfg.gae_block_elems or comp.cfg.block_elems
    errs = np.linalg.norm((hb - dec_sharded).reshape(-1, d_gae), axis=1)
    check("sharded_decompress",
          bool(np.allclose(dec_sharded, dec_single, rtol=1e-5, atol=1e-6))
          and float(errs.max()) <= TAU * (1 + 1e-5),
          f"max block l2 {float(errs.max()):.4f} <= tau={TAU}, "
          f"max |recon diff| = "
          f"{float(np.max(np.abs(dec_sharded - dec_single))):.3g}")

    import warnings
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = comp.compress(hb, tau=TAU, chunk_hyperblocks=4)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    check("options_shim",
          archive_io.serialize_archive(legacy) == blob_single
          and len(dep) == 1,
          f"{len(dep)} DeprecationWarning(s)")

    ok = all(c["ok"] for c in checks)
    print(json.dumps({"ok": ok, "devices": n_dev, "shards": want,
                      "checks": checks}, indent=2))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
