"""Mesh construction + stripe-to-shard span alignment for the sharded
compress pipeline.

The paper's hyper-block design makes archive chunks independently codable,
which is exactly the property that lets the fused device programs in
``core/exec.py`` scale past one device: the hyper-block axis is a pure data
axis.  This module owns the three mesh-level concerns:

* **Mesh construction** (``resolve_mesh`` / ``make_compress_mesh``): a 1-D
  ``jax.sharding.Mesh`` over the hyper-block data axis ``MESH_AXIS`` —
  ``"hb"`` — reusing the naming conventions of ``parallel/sharding.py``
  (named axes, ``PartitionSpec`` replication for parameters).
* **Stripe-to-shard span alignment** (``plan_shard_groups``): the stripe IS
  the archive chunk, so alignment is a span-planning problem, not a format
  change.  Consecutive equal-width stripes are grouped ``n_shards`` at a
  time; each group is stacked into ONE ``shard_map`` call where every shard
  processes EXACTLY one stripe.  Per-shard block shapes therefore equal the
  single-device per-stripe shapes, which is what makes the sharded archive
  byte-identical to the single-device archive (bit-equal floats, not
  floating-point luck).  Ragged tails — the last short stripe, or a final
  group with fewer than ``n_shards`` stripes — fall back to the per-stripe
  single-device path.
* **Host-local entropy fan-out**: because shard boundaries coincide with
  stripe boundaries, every chunk's GAE + entropy coding consumes only rows
  its own shard produced — nothing ever crosses a shard boundary on the
  host side.

The PCA basis fit also scales over the same axis: ``fit_pca_basis_sharded``
wires ``core/gae.py``'s existing ``fit_pca_basis(axis_name=...)`` psum path
through a ``shard_map`` trace — each shard computes its local D x D residual
covariance, one ``psum`` makes it global (zero-padded rows contribute exactly
nothing to ``r.T @ r``, so padding to an even shard split is exact).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.errors import ConfigError
from repro.core.options import MESH_AXIS

Span = tuple  # (hb_start, n_hyperblocks)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def available_devices() -> int:
    return len(jax.devices())


def make_compress_mesh(n_shards: Optional[int] = None) -> Mesh:
    """1-D compress mesh over the hyper-block data axis.

    ``n_shards=None`` takes every addressable device.  Requesting more shards
    than devices is a :class:`ConfigError` — the same condition would
    otherwise surface as an opaque ``jax.make_mesh`` failure mid-run.
    """
    have = available_devices()
    want = have if n_shards is None else int(n_shards)
    if want < 1:
        raise ConfigError(f"compress mesh needs >= 1 shard, got {want}")
    if want > have:
        raise ConfigError(
            f"compress mesh wants {want} shards but only {have} device(s) "
            f"are addressable (XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=N forces N virtual CPU devices)")
    return jax.make_mesh((want,), (MESH_AXIS,))


def resolve_mesh(spec) -> Optional[Mesh]:
    """Resolve a ``CompressOptions.mesh`` field to a concrete ``Mesh``.

    ``None`` and meshes/counts of size 1 resolve to ``None`` (single-device
    execution: the sharded path would add wrapper overhead for nothing and
    the unsharded path is the byte-identity reference).
    """
    if spec is None:
        return None
    if isinstance(spec, int):
        if spec <= 1:
            return None
        return make_compress_mesh(spec)
    if not isinstance(spec, Mesh):
        raise ConfigError(f"cannot resolve a {type(spec).__name__} into a "
                          f"compress mesh")
    if MESH_AXIS not in spec.axis_names:
        raise ConfigError(f"compress mesh is missing the {MESH_AXIS!r} axis "
                          f"(axes: {tuple(spec.axis_names)})")
    return spec if spec.shape[MESH_AXIS] > 1 else None


def mesh_shards(mesh: Optional[Mesh]) -> int:
    return 1 if mesh is None else int(mesh.shape[MESH_AXIS])


# ---------------------------------------------------------------------------
# stripe-to-shard span alignment
# ---------------------------------------------------------------------------

def plan_shard_groups(spans: Sequence[Span], n_shards: int
                      ) -> tuple[list[list[Span]], list[Span]]:
    """Align the stripe tiling to shard boundaries.

    Returns ``(groups, tail)``: ``groups`` is a list of span groups, each
    exactly ``n_shards`` consecutive spans of EQUAL width (one stripe per
    shard — the alignment invariant the byte-identity guarantee rests on);
    ``tail`` is every remaining span (ragged width or an incomplete final
    group), to be run through the per-stripe single-device path.

    The function is a pure reindexing of the pipeline's existing
    ``stripe_spans`` tiling: it never changes chunk boundaries, so archives
    produced with and without a mesh have identical section tables.
    """
    if n_shards < 1:
        raise ConfigError(f"plan_shard_groups needs n_shards >= 1, "
                          f"got {n_shards}")
    spans = list(spans)
    if n_shards == 1:
        return [], spans
    groups: list[list[Span]] = []
    tail: list[Span] = []
    i = 0
    while i + n_shards <= len(spans):
        cand = spans[i:i + n_shards]
        widths = {int(w) for _, w in cand}
        if len(widths) == 1:
            groups.append(cand)
            i += n_shards
        else:
            break
    tail.extend(spans[i:])
    return groups, tail


def group_slice(group: Sequence[Span]) -> tuple[int, int]:
    """A shard group covers one CONTIGUOUS hyper-block range (spans are
    consecutive by construction): returns ``(start, stop)``."""
    start = int(group[0][0])
    stop = int(group[-1][0] + group[-1][1])
    return start, stop


# ---------------------------------------------------------------------------
# sharded PCA basis fit (psum covariance)
# ---------------------------------------------------------------------------

def fit_pca_basis_sharded(residuals: np.ndarray, mesh: Mesh) -> np.ndarray:
    """Global-exact PCA basis of ``(N, D)`` residuals over the mesh.

    Each shard computes its local ``r.T @ r`` covariance; ``core.gae``'s
    existing ``fit_pca_basis(axis_name=...)`` psums the D x D matrix across
    the ``hb`` axis, so communication is O(D^2) independent of N.  Rows are
    zero-padded to an even shard split — zero rows add exactly nothing to
    the covariance, so the result is the psum of the true per-shard
    covariances.  Every shard then runs the same ``eigh`` on the same global
    covariance, so the replicated basis is consistent by construction.
    """
    from repro.core import exec as exec_mod
    from repro.core import gae

    n_shards = mesh_shards(mesh)
    r = np.asarray(residuals, np.float32)
    n, d = r.shape
    pad = (-n) % n_shards
    if pad:
        r = np.concatenate([r, np.zeros((pad, d), np.float32)], axis=0)

    def local_fit(rr):
        return gae.fit_pca_basis(rr, axis_name=MESH_AXIS)

    from jax.experimental.shard_map import shard_map
    fn = shard_map(local_fit, mesh=mesh, in_specs=(P(MESH_AXIS),),
                   out_specs=P(), check_rep=False)
    fit = exec_mod.cache().get("fit_pca_basis_sharded", fn, mesh=mesh)
    with exec_mod.stage("fit_basis_sharded", r.size):
        return np.asarray(jax.device_get(fit(jnp.asarray(r))))
