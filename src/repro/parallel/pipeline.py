"""GPipe-style pipeline parallelism via shard_map + lax.ppermute.

An alternative layout for the pod axis (DESIGN.md §6): instead of extending
data parallelism across pods, the layer stack is split into P stages, one per
pipe-axis slice; microbatches stream through the stages with activations
forwarded by ``lax.ppermute`` (the jax-native point-to-point — no NCCL-style
send/recv emulation).

The schedule is plain GPipe: M microbatches, M + P - 1 ticks, bubble fraction
(P-1)/(M+P-1).  Every device executes identical code; stage-0 injection,
last-stage collection, and the bubble are expressed as masked selects, so the
whole schedule jits to a single fori_loop — no per-tick retracing.

``pipeline_apply`` is intentionally generic: ``stage_fn(stage_params, x)``
is one pipeline stage (usually a scan over that stage's layer slice).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
Array = jax.Array


def pipeline_apply(stage_fn: Callable, stage_params: PyTree, x: Array, *,
                   mesh: Mesh, axis: str = "pipe") -> Array:
    """Run x through P sequential stages with a GPipe schedule.

    stage_params: pytree with leading axis P (stage-major), sharded over
    ``axis``.  x: (M, mb, ...) microbatched input, replicated.  Returns
    (M, mb, ...) outputs (gathered from the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_device(params, xs):
        # params: (1, ...) this stage's slice;  xs: (M, mb, ...) replicated
        stage = jax.lax.axis_index(axis)
        my_params = jax.tree.map(lambda p: p[0], params)
        buf = jnp.zeros_like(xs[0])
        out = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, out = carry
            # stage-0 injection of microbatch t (clamped gather; masked)
            inj = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            use_inj = jnp.logical_and(stage == 0, t < n_micro)
            buf = jnp.where(use_inj, inj, buf)
            y = stage_fn(my_params, buf)
            # last-stage collection of finished microbatch t - (P-1)
            slot = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            collect = jnp.logical_and(stage == n_stages - 1,
                                      t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out, slot, 0, keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(collect, y, cur), slot, 0)
            # forward activations to the next stage (ring; wrap discarded)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, out

        _, out = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick, (buf, out))
        # every device returns its `out`; only the last stage's is real.
        # psum-mask so the replicated output is the last stage's tensor.
        mask = (stage == n_stages - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = jax.shard_map(per_device, mesh=mesh,
                       in_specs=(spec_params, P()), out_specs=P(),
                       check_vma=False)
    return fn(stage_params, x)


def gpipe_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
