"""Partition rules: parameter PartitionSpecs + activation sharding constraints.

Mesh axes (launch/mesh.py): ("data", "model") single-pod, ("pod", "data",
"model") multi-pod.  Batch shards over ("pod","data") [DP], weights over
"model" [TP/EP]; see DESIGN.md §6.

Activation constraints are applied through ``constrain(x, kind)``, which is a
no-op unless a launcher has installed rules via ``activation_sharding(...)`` —
so single-device smoke tests trace the very same model code with zero sharding
machinery.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------

_ACTIVE: Optional[dict] = None


def activation_rules(multi_pod: bool, sp: bool = False,
                     kv_seq_shard: bool = False) -> dict:
    """``sp=True`` = Megatron-style sequence parallelism: residual-stream
    activations between attention/MLP blocks are sharded over the model axis
    along the SEQUENCE dim, so GSPMD lowers the TP boundary as
    reduce-scatter + all-gather (half the bytes of the all-reduce it replaces,
    and overlappable) — a §Perf hillclimb lever."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "tokens": P(dp, None),                 # (B, S)
        "act_btd": P(dp, "model", None) if sp  # (B, S, D)
        else P(dp, None, None),
        "act_btf": P(dp, None, "model"),       # (B, S, F) — ffn hidden sharded
        "act_bthd": P(dp, None, "model", None),  # (B, S, H, hd) — heads sharded
        "logits": P(dp, None, "model"),        # (B, S, V) — vocab sharded
        # (B, S, KV, hd); long-context decode at batch < dp shards the
        # sequence axis instead (sequence parallelism, DESIGN.md §6)
        "kv_cache": P(None, dp, "model", None) if kv_seq_shard
        else P(dp, None, "model", None),
        "expert_buf": P("model", None, None),  # (E, C, D)
        # flattened (B*S, D) token table in the MoE dispatch/combine: the
        # (b,s,d)->(t,d) reshape breaks GSPMD's propagated sharding (b on dp,
        # s on model under SP are not jointly expressible on t), which
        # otherwise replicates 1M-token fp32 buffers (§Perf llama4 it3)
        "tokens_flat": P(dp, None),
        "tokens_grouped": P(dp, None, None),   # (G, T/G, D) grouped dispatch
    }


@contextlib.contextmanager
def activation_sharding(rules: Optional[dict]):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, rules
    try:
        yield
    finally:
        _ACTIVE = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    if _ACTIVE is None or kind not in _ACTIVE:
        return x
    spec = _ACTIVE[kind]
    if x.ndim != len(spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# parameter partition rules
# ---------------------------------------------------------------------------

# (regex over the param path, spec WITHOUT the scan-stack leading axis)
_PARAM_RULES: list[tuple[str, P]] = [
    (r"embed/w$",            P("model", None)),          # (V, D) vocab-sharded
    (r"unembed/w$",          P(None, "model")),          # (D, V)
    (r"pos_embed/w$",        P(None, None)),
    (r"(wq|wk|wv)$",         P(None, "model", None)),    # (D, H, hd) head-sharded
    (r"wo$",                 P("model", None, None)),    # (H, hd, D)
    (r"(bq|bk|bv)$",         P("model", None)),
    # MoE rules MUST precede the generic ffn rules (longest-match-first).
    (r"moe/router$",         P(None, None)),
    (r"moe/shared/(w_gate|w_in)$", P(None, "model")),    # shared expert (D, F)
    (r"moe/shared/w_out$",   P("model", None)),
    (r"moe/(w_gate|w_in)$",  P("model", None, None)),    # (E, D, F) EP
    (r"moe/w_out$",          P("model", None, None)),    # (E, F, D) EP
    (r"moe_fs/(w_gate|w_in)$", P(None, None, "model")),  # E % tp != 0: shard F
    (r"moe_fs/w_out$",       P(None, "model", None)),
    (r"(w_gate|w_in)$",      P(None, "model")),          # (D, F)
    (r"w_out$",              P("model", None)),          # (F, D)
    (r"(w_x|w_gate_branch)$", P(None, "model")),         # RG-LRU (D, lru)
    (r"(conv/w|conv/b)$",    P(None, "model")),          # (cw, lru)
    (r"lru/(alpha|in_gate/w|rec_gate/w)$", P(None, "model")),
    (r"lru/(in_gate|rec_gate)/b$", P("model",)),
    (r"lru_out$",            P("model", None)),          # (lru, D)
    (r"ssd/in_proj$",        P(None, "model")),          # (D, d_inner+...)
    (r"ssd/out_proj$",       P("model", None)),          # (d_inner, D)
    (r"ssd/conv_w$",         P(None, "model")),
    (r"ssd/(A_log|dt_bias|D|norm_scale)$", P("model",)),
    (r"(scale|bias|b_in|b_out|gate)$", None),            # norms / biases: replicated
]


def param_spec_for_path(path: str, ndim: int, *, scanned: int = 0) -> P:
    """Match a parameter path to its PartitionSpec; prepend one unsharded axis
    per stacked-layer level (``scanned`` — the VLM has nested groups/selfs
    stacks = 2); fall back to replication."""
    for pattern, spec in _PARAM_RULES:
        if re.search(pattern, path):
            if spec is None:
                spec = P()
            parts = list(spec)
            break
    else:
        parts = []
    parts = [None] * scanned + parts
    # pad/truncate to ndim
    parts = parts[:ndim] + [None] * (ndim - len(parts))
    return P(*parts)


def _path_str(path) -> str:
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(str(entry.key))
        elif hasattr(entry, "idx"):
            out.append(str(entry.idx))
        else:
            out.append(str(entry))
    return "/".join(out)


def param_partition_specs(params, scanned_prefixes: tuple[str, ...] = (
        "layers", "triples", "groups", "selfs", "enc_layers", "dec_layers",
        "tail"),
        *, fsdp_axis: Optional[str] = None, fsdp_size: int = 0,
        min_fsdp_elems: int = 65536, tp_size: int = 0) -> dict:
    """PartitionSpec pytree matching ``params``.

    Leaves under any of ``scanned_prefixes`` carry a leading stacked-layer axis
    that is never sharded.

    ``fsdp_axis`` (ZeRO-3 / MaxText-style fully-sharded params): additionally
    shard the first unsharded *feature* dim divisible by ``fsdp_size`` on every
    leaf with >= ``min_fsdp_elems`` elements.  GSPMD then all-gathers each
    layer's params at use and reduce-scatters gradients — parameter and
    optimizer memory drop by the data-axis size.  The stacked-layer (scan)
    axis is never chosen.
    """
    def spec(path, leaf):
        p = _path_str(path)
        n_stack = sum(seg in scanned_prefixes for seg in p.split("/"))
        base = param_spec_for_path(p, jnp.ndim(leaf), scanned=n_stack)
        shape = getattr(leaf, "shape", ())
        parts = list(base)
        # MoE divisibility fallback (DESIGN.md §6): when the expert count
        # does not divide the model axis (granite: 40 % 16 != 0), shard the
        # per-expert ffn dim instead of the expert axis.
        if tp_size > 1 and re.search(r"moe/w_(gate|in|out)$", p):
            e_dim = n_stack
            if shape[e_dim] % tp_size != 0:
                parts[e_dim] = None
                f_dim = len(parts) - (2 if p.endswith("w_out") else 1)
                parts[f_dim] = "model"
        size = 1
        for d in shape:
            size *= d
        if (fsdp_axis and fsdp_size > 1 and size >= min_fsdp_elems
                and len(shape) >= 2):
            for i in range(n_stack, len(parts)):
                if parts[i] is None and shape[i] % fsdp_size == 0:
                    parts[i] = fsdp_axis
                    break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, params)


def named_shardings(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_spec(multi_pod: bool) -> P:
    return P(("pod", "data") if multi_pod else ("data",), None)


# ---------------------------------------------------------------------------
# decode-state partition rules (serve_step dry-run cells)
# ---------------------------------------------------------------------------

def decode_state_specs(state_shapes, multi_pod: bool, *,
                       batch: int, dp_size: int, seq_len: int = 0,
                       tp_size: int = 16):
    """PartitionSpec pytree for a model's DecodeState (shapes from
    eval_shape).

    Rules are SHAPE-driven, not name-driven: custom pytree nodes (KVCache is
    a registered NamedTuple) flatten positionally, so leaf names are not
    visible in key paths.  Classification:

      * a leaf with an axis == ``seq_len``  -> KV-style cache
        (..., B, S, KV, hd): dp on the batch axis, "model" on the KV-head
        axis (padded to divide tp), hd replicated;
      * any other leaf with an axis == ``batch`` -> per-batch recurrent state
        (SSD h, conv ring buffers, RG-LRU h, encoder cross-KV): dp on the
        batch axis, "model" on the first later axis divisible by tp;
      * everything else (positions, scalars) -> replicated.

    When ``batch < dp_size`` (long_500k: batch 1) the data axis cannot shard
    batch; KV caches shard the sequence axis over it instead (sequence
    parallelism, DESIGN.md §6) and other per-batch state is replicated.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    seq_shard = batch < dp_size

    def spec(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        ndim = len(shape)
        if ndim == 0:
            return P()
        parts: list = [None] * ndim
        s_idx = next((i for i, d in enumerate(shape)
                      if seq_len and d == seq_len), None)
        b_idx = next((i for i, d in enumerate(shape) if d == batch), None)
        if s_idx is not None and ndim >= 3:
            # KV cache (..., B, S, KV, hd)
            kv_idx = s_idx + 1 if s_idx + 1 < ndim else None
            if seq_shard:
                parts[s_idx] = dp
            elif b_idx is not None and b_idx < s_idx:
                parts[b_idx] = dp
            if kv_idx is not None and shape[kv_idx] % tp_size == 0:
                parts[kv_idx] = "model"
            return P(*parts)
        if b_idx is not None and ndim >= 2:
            if not seq_shard:
                parts[b_idx] = dp
            for i in range(b_idx + 1, ndim):
                if shape[i] % tp_size == 0:
                    parts[i] = "model"
                    break
            return P(*parts)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, state_shapes)
