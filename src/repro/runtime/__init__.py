"""Production runtime: checkpoint/restart, failure handling, the durable
archive container (archive_io) + fault-injection harness (faultinject), and
the paper's compression technique applied where a 1000-node deployment bleeds
bytes — gradient all-reduce, KV cache, and checkpoint storage (DESIGN.md §2)."""
