"""Production runtime: checkpoint/restart, failure handling, and the paper's
compression technique applied where a 1000-node deployment bleeds bytes —
gradient all-reduce, KV cache, and checkpoint storage (DESIGN.md §2)."""
