"""Durable, self-validating on-disk archive container (.rba).

The in-memory ``Archive`` (repro.core.pipeline) is striped into hyper-block
chunks; this module owns the byte-level container: a magic + versioned header,
a digest-protected section table, and one self-framed section per chunk, so
that

* any flipped bit, torn write, or truncation is DETECTED (CRC32 fast path,
  sha256 strong path, per section), and
* a corrupted chunk section degrades to losing only its own hyper-blocks —
  every other chunk still decodes with the paper's per-block l2 <= tau
  guarantee intact (``decompress(strict=False)``).

No pickle is used anywhere on the read path: every structure is parsed from
explicit little-endian framing with bounds checks, and all failures raise the
typed ``ArchiveError`` taxonomy from ``repro.core.errors``.

Layout (all integers little-endian; see docs/ARCHIVE_FORMAT.md)::

    magic(8) version(u32) n_sections(u32) table_len(u64)
    [ name_len(u16) name(utf-8) offset(u64) length(u64) crc32(u32) sha256(32) ]*
    table_crc(u32)                       # CRC32 of everything above
    <section payloads, concatenated>

Sections: ``meta`` (JSON) then ``chunk/<i>`` blobs.  Writes are atomic:
tmp file + fsync + rename, with bounded retry/backoff.
"""
from __future__ import annotations

import hashlib
import json
import os
import struct
import time
import zlib
from typing import Optional

import numpy as np

from repro.core import entropy
from repro.core.errors import (ArchiveError, ChecksumMismatch, MalformedStream,
                               TruncatedArchive)
from repro.core.pipeline import Archive, ArchiveChunk

MAGIC = b"\x89RBA\r\n\x1a\n"
VERSION = 1
_PROLOGUE = struct.Struct("<8sIIQ")
_SECTION_FIXED = struct.Struct("<QQI32s")
_META_NAME = "meta"

# Caps applied while parsing untrusted framing, far above anything the encoder
# emits but small enough that a fuzzed length field cannot balloon memory.
MAX_SECTIONS = 1 << 20
MAX_SYMBOLS = 1 << 24
MAX_COUNT = 1 << 40


# ---------------------------------------------------------------------------
# atomic writes
# ---------------------------------------------------------------------------

def atomic_write_bytes(path: str, data: bytes, *, retries: int = 3,
                       backoff: float = 0.05) -> None:
    """Write ``data`` to ``path`` atomically (tmp + fsync + rename), retrying
    transient OS failures with exponential backoff."""
    tmp = f"{path}.tmp.{os.getpid()}"
    last: Optional[OSError] = None
    for attempt in range(retries + 1):
        try:
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            dirname = os.path.dirname(os.path.abspath(path))
            try:    # persist the rename itself; best-effort on odd filesystems
                dfd = os.open(dirname, os.O_RDONLY)
                try:
                    os.fsync(dfd)
                finally:
                    os.close(dfd)
            except OSError:
                pass
            return
        except OSError as e:
            last = e
            if os.path.exists(tmp):
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
            if attempt < retries:
                time.sleep(backoff * (2 ** attempt))
    raise OSError(f"failed to write {path!r} after {retries + 1} attempts") from last


# ---------------------------------------------------------------------------
# bounded little-endian readers
# ---------------------------------------------------------------------------

class _Reader:
    """Cursor over untrusted bytes; every read is bounds-checked."""

    def __init__(self, buf: bytes, what: str):
        self.buf = buf
        self.off = 0
        self.what = what

    def take(self, n: int) -> bytes:
        if n < 0 or self.off + n > len(self.buf):
            raise TruncatedArchive(
                f"{self.what}: need {n} bytes at offset {self.off}, "
                f"have {len(self.buf) - self.off}")
        out = self.buf[self.off:self.off + n]
        self.off += n
        return out

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self.take(8))[0]

    def done(self) -> bool:
        return self.off == len(self.buf)


# ---------------------------------------------------------------------------
# Huffman stream framing
# ---------------------------------------------------------------------------

def _pack_stream(s: Optional[entropy.HuffmanStream]) -> bytes:
    if s is None:
        return struct.pack("<QI", 0, 0) + struct.pack("<Q", 0)
    syms = np.asarray(s.book.symbols, "<i8").tobytes()
    lens = np.asarray(s.book.lengths, np.uint8).tobytes()
    return (struct.pack("<QI", s.count, s.book.symbols.size) + syms + lens
            + struct.pack("<Q", len(s.payload)) + s.payload)


def _unpack_stream(r: _Reader) -> Optional[entropy.HuffmanStream]:
    count = r.u64()
    n_sym = r.u32()
    if count > MAX_COUNT:
        raise MalformedStream(f"{r.what}: absurd symbol count {count}")
    if n_sym > MAX_SYMBOLS:
        raise MalformedStream(f"{r.what}: absurd codebook size {n_sym}")
    if count > 0 and n_sym == 0:
        raise MalformedStream(f"{r.what}: {count} symbols with empty book")
    symbols = np.frombuffer(r.take(8 * n_sym), "<i8").astype(np.int64)
    lengths = np.frombuffer(r.take(n_sym), np.uint8)
    payload_len = r.u64()
    payload = r.take(payload_len)
    if count == 0 and n_sym == 0:
        return None
    book = entropy.rebuild_book(symbols, lengths)
    return entropy.HuffmanStream(payload=payload, book=book, count=int(count))


# ---------------------------------------------------------------------------
# chunk framing
# ---------------------------------------------------------------------------

_FLAG_GAE = 1
_FLAG_GAE_COEFFS = 2
_FLAG_VERBATIM = 4   # quarantine fallback: deflate-packed raw float32 stripe


def _pack_chunk(c: ArchiveChunk) -> bytes:
    if c.verbatim_blob:
        # quarantined stripe: the payload is the stripe itself (lossless),
        # no latent/GAE streams exist
        return b"".join([
            struct.pack("<IIBB", c.hb_start, c.n_hyperblocks, 0,
                        _FLAG_VERBATIM),
            struct.pack("<Q", len(c.verbatim_blob)), c.verbatim_blob])
    flags = 0
    if c.gae_index_blob:
        flags |= _FLAG_GAE
    if c.gae_coeff_stream is not None:
        flags |= _FLAG_GAE_COEFFS
    parts = [struct.pack("<IIBB", c.hb_start, c.n_hyperblocks,
                         len(c.bae_streams), flags),
             _pack_stream(c.hb_stream)]
    parts += [_pack_stream(s) for s in c.bae_streams]
    if flags & _FLAG_GAE:
        if flags & _FLAG_GAE_COEFFS:
            parts.append(_pack_stream(c.gae_coeff_stream))
        parts.append(struct.pack("<I", len(c.gae_index_blob)))
        parts.append(c.gae_index_blob)
        parts.append(struct.pack("<I", len(c.gae_binexp_blob)))
        parts.append(c.gae_binexp_blob)
    return b"".join(parts)


def _unpack_chunk(blob: bytes, name: str) -> ArchiveChunk:
    r = _Reader(blob, name)
    hb_start = r.u32()
    n_hb = r.u32()
    n_bae = r.u8()
    flags = r.u8()
    if n_hb == 0:
        raise MalformedStream(f"{name}: empty chunk")
    if flags & _FLAG_VERBATIM:
        if flags != _FLAG_VERBATIM or n_bae != 0:
            raise MalformedStream(
                f"{name}: verbatim chunk with conflicting flags={flags} "
                f"n_bae={n_bae}")
        verbatim = r.take(r.u64())
        if not verbatim:
            raise MalformedStream(f"{name}: empty verbatim payload")
        if not r.done():
            raise MalformedStream(
                f"{name}: {len(blob) - r.off} trailing bytes")
        return ArchiveChunk(hb_start=hb_start, n_hyperblocks=n_hb,
                            hb_stream=None, bae_streams=[],
                            gae_coeff_stream=None, gae_index_blob=b"",
                            gae_binexp_blob=b"", verbatim_blob=verbatim)
    hb_stream = _unpack_stream(r)
    if hb_stream is None:
        raise MalformedStream(f"{name}: missing hyper-block latent stream")
    bae_streams = []
    for _ in range(n_bae):
        s = _unpack_stream(r)
        if s is None:
            raise MalformedStream(f"{name}: missing BAE stream")
        bae_streams.append(s)
    coeff_stream = None
    index_blob = binexp_blob = b""
    if flags & _FLAG_GAE:
        if flags & _FLAG_GAE_COEFFS:
            coeff_stream = _unpack_stream(r)
            if coeff_stream is None:
                raise MalformedStream(f"{name}: missing GAE coefficient stream")
        index_blob = r.take(r.u32())
        binexp_blob = r.take(r.u32())
    if not r.done():
        raise MalformedStream(f"{name}: {len(blob) - r.off} trailing bytes")
    return ArchiveChunk(hb_start=hb_start, n_hyperblocks=n_hb,
                        hb_stream=hb_stream, bae_streams=bae_streams,
                        gae_coeff_stream=coeff_stream,
                        gae_index_blob=index_blob, gae_binexp_blob=binexp_blob)


# ---------------------------------------------------------------------------
# container serialize / deserialize
# ---------------------------------------------------------------------------

def _chunk_name(i: int) -> str:
    return f"chunk/{i:06d}"


def chunk_section_name(i: int) -> str:
    """Public alias for the per-chunk section naming scheme."""
    return _chunk_name(i)


def build_meta_blob(*, n_hyperblocks: int, n_values: int,
                    chunk_hyperblocks: int, gae_dim: int,
                    spans: list) -> bytes:
    """The ``meta`` section bytes for a given stripe tiling.  Shared between
    ``serialize_archive`` and the streaming writer so both produce identical
    meta sections for the same geometry — ``spans`` is known BEFORE any chunk
    is encoded, which is what lets the streaming writer lay out the whole
    section table up front."""
    meta = {
        "format": VERSION,
        "n_hyperblocks": int(n_hyperblocks),
        "n_values": int(n_values),
        "chunk_hyperblocks": int(chunk_hyperblocks),
        "gae_dim": int(gae_dim),
        "n_chunks": len(spans),
        "chunks": [[int(s), int(n)] for s, n in spans],
    }
    return json.dumps(meta, sort_keys=True).encode()


def pack_head(entries: list) -> bytes:
    """Prologue + section table + table CRC for ``entries`` =
    ``[(name, offset, length, crc32, sha256_digest), ...]``."""
    table = bytearray()
    for name, offset, length, crc, sha in entries:
        nb = name.encode()
        table += struct.pack("<H", len(nb)) + nb
        table += _SECTION_FIXED.pack(offset, length, crc, sha)
    head = _PROLOGUE.pack(MAGIC, VERSION, len(entries), len(table)) + table
    return head + struct.pack("<I", zlib.crc32(head))


def head_size(section_names: list) -> int:
    """Byte length of ``pack_head`` output for the given section names —
    fixed as soon as the stripe tiling is known, so the streaming writer can
    reserve the header region before any payload exists."""
    table_len = sum(2 + len(n.encode()) + _SECTION_FIXED.size
                    for n in section_names)
    return _PROLOGUE.size + table_len + 4


def pack_chunk_section(c: ArchiveChunk) -> bytes:
    """Public alias of the chunk section framing encoder."""
    return _pack_chunk(c)


def unpack_chunk_section(blob: bytes, name: str = "chunk") -> ArchiveChunk:
    """Public alias of the chunk section framing decoder (typed errors)."""
    return _unpack_chunk(blob, name)


def chunk_section_size(c: ArchiveChunk) -> int:
    """Exact ``len(pack_chunk_section(c))`` from framing arithmetic (no bytes
    built) — the streaming writer's span precomputation."""
    return _chunk_size(c)


def serialize_archive(archive: Archive) -> bytes:
    """Serialize to the container byte layout (deterministic)."""
    if any(c is None for c in archive.chunks):
        raise ValueError("cannot serialize an archive with damaged chunks")
    meta_blob = build_meta_blob(
        n_hyperblocks=archive.n_hyperblocks, n_values=archive.n_values,
        chunk_hyperblocks=archive.chunk_hyperblocks, gae_dim=archive.gae_dim,
        spans=[(c.hb_start, c.n_hyperblocks) for c in archive.chunks])
    sections = [(_META_NAME, meta_blob)]
    sections += [(_chunk_name(i), _pack_chunk(c))
                 for i, c in enumerate(archive.chunks)]

    entries = []
    offset = 0
    for name, blob in sections:
        entries.append((name, offset, len(blob), zlib.crc32(blob),
                        hashlib.sha256(blob).digest()))
        offset += len(blob)
    return pack_head(entries) + b"".join(blob for _, blob in sections)


def _stream_size(s: Optional[entropy.HuffmanStream]) -> int:
    """len(_pack_stream(s)) from framing arithmetic, no bytes built."""
    if s is None:
        return 12 + 8
    return 12 + 9 * s.book.symbols.size + 8 + len(s.payload)


def _chunk_size(c: ArchiveChunk) -> int:
    """len(_pack_chunk(c)) from framing arithmetic, no bytes built."""
    if c.verbatim_blob:
        return 10 + 8 + len(c.verbatim_blob)
    size = 10 + _stream_size(c.hb_stream)
    size += sum(_stream_size(s) for s in c.bae_streams)
    if c.gae_index_blob:
        if c.gae_coeff_stream is not None:
            size += _stream_size(c.gae_coeff_stream)
        size += 4 + len(c.gae_index_blob) + 4 + len(c.gae_binexp_blob)
    return size


def serialized_size(archive: Archive) -> int:
    """Exact ``len(serialize_archive(archive))`` WITHOUT building the payload
    bytes: O(sections) arithmetic over the framing layout (the meta JSON is
    the only section actually rendered).  Keeps ``Archive.compressed_bytes``
    / ``compression_ratio`` cheap enough to query inside benchmark sweeps."""
    if any(c is None for c in archive.chunks):
        raise ValueError("cannot size an archive with damaged chunks")
    meta_blob = build_meta_blob(
        n_hyperblocks=archive.n_hyperblocks, n_values=archive.n_values,
        chunk_hyperblocks=archive.chunk_hyperblocks, gae_dim=archive.gae_dim,
        spans=[(c.hb_start, c.n_hyperblocks) for c in archive.chunks])
    names = [_META_NAME] + [_chunk_name(i)
                            for i in range(len(archive.chunks))]
    return (head_size(names) + len(meta_blob)
            + sum(_chunk_size(c) for c in archive.chunks))


def deserialize_archive(data: bytes, *, strict: bool = True) -> Archive:
    """Parse + verify a container.  ``strict=True`` raises on ANY damage;
    ``strict=False`` tolerates damaged chunk sections (they become ``None``
    entries with reasons in ``Archive.chunk_errors``) but still raises if the
    header, section table, or meta section are unusable."""
    if len(data) < _PROLOGUE.size + 4:
        raise TruncatedArchive(
            f"file of {len(data)} bytes is shorter than the header")
    magic, version, n_sections, table_len = _PROLOGUE.unpack_from(data)
    if magic != MAGIC:
        raise MalformedStream(f"bad magic {magic!r}")
    if version != VERSION:
        raise MalformedStream(f"unsupported container version {version}")
    if n_sections > MAX_SECTIONS:
        raise MalformedStream(f"absurd section count {n_sections}")
    head_len = _PROLOGUE.size + table_len
    if head_len + 4 > len(data):
        raise TruncatedArchive("section table extends past end of file")
    declared = struct.unpack_from("<I", data, head_len)[0]
    if zlib.crc32(data[:head_len]) != declared:
        raise ChecksumMismatch("section table CRC mismatch (header damage)")

    r = _Reader(data[_PROLOGUE.size:head_len], "section table")
    payload_base = head_len + 4
    table: dict[str, tuple[int, int, int, bytes]] = {}
    for _ in range(n_sections):
        try:
            name = r.take(r.u16()).decode()
        except UnicodeDecodeError as e:
            raise MalformedStream(f"undecodable section name: {e}") from e
        off, length, crc, sha = _SECTION_FIXED.unpack(
            r.take(_SECTION_FIXED.size))
        if name in table:
            raise MalformedStream(f"duplicate section {name!r}")
        table[name] = (off, length, crc, sha)
    if not r.done():
        raise MalformedStream("trailing bytes in section table")

    def read_section(name: str) -> bytes:
        off, length, crc, sha = table[name]
        lo, hi = payload_base + off, payload_base + off + length
        if hi > len(data):
            raise TruncatedArchive(
                f"section {name!r} extends past end of file")
        blob = data[lo:hi]
        if zlib.crc32(blob) != crc:
            raise ChecksumMismatch(f"section {name!r} CRC32 mismatch")
        if hashlib.sha256(blob).digest() != sha:
            raise ChecksumMismatch(f"section {name!r} sha256 mismatch")
        return blob

    if _META_NAME not in table:
        raise MalformedStream("container has no meta section")
    try:
        meta = json.loads(read_section(_META_NAME).decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise MalformedStream(f"corrupt meta section: {e}") from e
    meta = _validate_meta(meta)

    chunks: list[Optional[ArchiveChunk]] = []
    chunk_errors: dict[int, str] = {}
    for i, (start, n_hb) in enumerate(meta["chunks"]):
        name = _chunk_name(i)
        try:
            if name not in table:
                raise TruncatedArchive(f"section {name!r} missing")
            chunk = _unpack_chunk(read_section(name), name)
            if chunk.hb_start != start or chunk.n_hyperblocks != n_hb:
                raise MalformedStream(
                    f"{name}: header range [{chunk.hb_start}, "
                    f"+{chunk.n_hyperblocks}] != meta range [{start}, +{n_hb}]")
        except ArchiveError as e:
            if strict:
                raise
            chunks.append(None)
            chunk_errors[i] = repr(e)
            continue
        chunks.append(chunk)
    return Archive(n_hyperblocks=meta["n_hyperblocks"],
                   n_values=meta["n_values"],
                   chunk_hyperblocks=meta["chunk_hyperblocks"],
                   gae_dim=meta["gae_dim"], chunks=chunks,
                   chunk_errors=chunk_errors)


def _validate_meta(meta) -> dict:
    if not isinstance(meta, dict):
        raise MalformedStream("meta section is not a JSON object")
    for key in ("n_hyperblocks", "n_values", "chunk_hyperblocks", "gae_dim",
                "n_chunks"):
        v = meta.get(key)
        if not isinstance(v, int) or v < 0:
            raise MalformedStream(f"meta field {key!r} invalid: {v!r}")
    chunks = meta.get("chunks")
    if (not isinstance(chunks, list) or len(chunks) != meta["n_chunks"]
            or not all(isinstance(c, list) and len(c) == 2
                       and all(isinstance(x, int) and x >= 0 for x in c)
                       for c in chunks)):
        raise MalformedStream("meta chunk table invalid")
    covered = 0
    for start, n_hb in chunks:
        if start != covered or n_hb == 0:
            raise MalformedStream("meta chunk table does not tile the "
                                  "hyper-block range")
        covered += n_hb
    if covered != meta["n_hyperblocks"]:
        raise MalformedStream(
            f"meta chunk table covers {covered} hyper-blocks, "
            f"declares {meta['n_hyperblocks']}")
    return meta


# ---------------------------------------------------------------------------
# file-level API
# ---------------------------------------------------------------------------

def write_archive(archive: Archive, path: str, *, retries: int = 3) -> int:
    """Serialize and atomically write ``archive``; returns bytes written."""
    blob = serialize_archive(archive)
    atomic_write_bytes(path, blob, retries=retries)
    return len(blob)


def read_archive(path: str, *, strict: bool = True) -> Archive:
    """Read + verify a container from disk (see ``deserialize_archive``)."""
    with open(path, "rb") as f:
        data = f.read()
    return deserialize_archive(data, strict=strict)
