"""Live chaos injection for the streaming compression pipeline.

``faultinject`` fuzzes archives at rest; this module attacks the pipeline
WHILE IT RUNS: a seeded ``ChaosInjector`` hooks into ``StreamScheduler``
(``chaos=`` argument; ``stream_compress(chaos=...)``) and, per
(stage, item, attempt), may inject

* a **transient fault**  — raises ``TransientStageError``; the retry ladder
  must absorb it (decision re-rolled per attempt, so retries can succeed),
* a **permanent fault**  — raises ``ChaosPermanentFault`` on EVERY attempt
  of that (stage, item); the quarantine ladder must convert the stripe into
  a lossless verbatim fallback chunk,
* a **hang**             — sleeps past the stage deadline; the watchdog must
  abandon the attempt instead of deadlocking the bounded queues.

All decisions are pure functions of ``(seed, stage, item, attempt)`` via
crc32 — independent of thread scheduling and of Python's per-process hash
seed — which is what makes a chaos run reproducible: same seed, same fault
schedule, same retry timeline, same quarantine set.

``run_chaos_check`` is the invariant harness the smoke gate runs: it streams
a dataset under injected chaos (twice) and asserts

1. **no deadlock** — the run finishes within a generous wall-clock budget;
2. **determinism** — both runs produce identical retry timelines and
   quarantine sets;
3. **guaranteed bound** — every chunk in the finalized container is either
   byte-identical to the batch path's chunk or a flagged verbatim fallback
   that decodes losslessly (error 0 <= tau);
4. **salvageable failure** — if the run does abort, ``<out>.partial`` is
   still tolerantly readable.

CLI (wired as a smoke.sh gate)::

    python -m repro.runtime.chaosinject --seed 0
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import threading
import time
import zlib
from typing import Optional

import numpy as np

from repro.core.errors import TransientStageError

CHAOS_STAGES = ("dispatch", "transfer", "host_encode")


class ChaosPermanentFault(RuntimeError):
    """An injected fault that no retry can clear (poison stripe)."""


def _unit(seed: int, *parts) -> float:
    """Deterministic uniform in [0, 1) from (seed, parts); crc32 plus a
    murmur-style finalizer (bare crc32 correlates across adjacent items)."""
    h = zlib.crc32(f"{seed}|".encode()
                   + "|".join(map(str, parts)).encode())
    h = ((h ^ (h >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    h = ((h ^ (h >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    return (h ^ (h >> 16)) / 2.0 ** 32


@dataclasses.dataclass
class ChaosSpec:
    """Seeded fault schedule.  Rates are per (stage, item) for permanent
    faults and per (stage, item, attempt) for transient faults and hangs,
    applied only to ``stages``."""
    seed: int = 0
    transient_rate: float = 0.0
    permanent_rate: float = 0.0
    hang_rate: float = 0.0
    hang_s: float = 0.5
    stages: tuple = CHAOS_STAGES


class ChaosInjector:
    """``StreamScheduler`` hook: consult ``before(stage, item, attempt)``
    ahead of every attempt.  Thread-safe; keeps injection counts."""

    def __init__(self, spec: ChaosSpec):
        self.spec = spec
        self._lock = threading.Lock()
        self.injected: dict[str, int] = {"transient": 0, "permanent": 0,
                                         "hang": 0}

    def _count(self, kind: str) -> None:
        with self._lock:
            self.injected[kind] += 1

    def before(self, stage: str, item: int, attempt: int) -> None:
        spec = self.spec
        if stage not in spec.stages:
            return
        if _unit(spec.seed, "perm", stage, item) < spec.permanent_rate:
            self._count("permanent")
            raise ChaosPermanentFault(
                f"chaos: permanent fault at {stage}[{item}]")
        if _unit(spec.seed, "hang", stage, item, attempt) < spec.hang_rate:
            self._count("hang")
            time.sleep(spec.hang_s)
            return
        if _unit(spec.seed, "trans", stage, item, attempt) \
                < spec.transient_rate:
            self._count("transient")
            raise TransientStageError(
                f"chaos: transient fault at {stage}[{item}] "
                f"attempt {attempt}")


# ---------------------------------------------------------------------------
# invariant harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChaosReport:
    scenario: str
    violations: list
    retries: int = 0
    deadline_hits: int = 0
    quarantined: int = 0
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        state = "OK" if self.ok else "FAIL"
        lines = [f"[{state}] {self.scenario}: {self.retries} retries, "
                 f"{self.deadline_hits} deadline hits, "
                 f"{self.quarantined} quarantined, {self.wall_s:.2f}s"]
        lines += [f"  VIOLATION: {v}" for v in self.violations]
        return "\n".join(lines)


def _run_with_watchdog(fn, budget_s: float):
    """Run ``fn`` on a thread with a wall-clock budget; returns
    ``(finished, result_or_exc)``.  A blown budget IS the deadlock signal —
    the stuck thread is daemonic and abandoned."""
    box: dict = {}
    done = threading.Event()

    def call():
        try:
            box["result"] = fn()
        except BaseException as e:   # retry-boundary: unpacked by caller
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=call, daemon=True, name="chaos-watchdog")
    t.start()
    if not done.wait(budget_s):
        return False, None
    if "error" in box:
        return True, box["error"]
    return True, box["result"]


def run_chaos_check(comp, hyperblocks, tau: float, spec: ChaosSpec,
                    out_path: str, *, scenario: str = "chaos",
                    chunk_hyperblocks: int = 7,
                    deadline_s: Optional[float] = None,
                    budget_s: float = 120.0) -> ChaosReport:
    """Stream ``hyperblocks`` under injected chaos and assert the
    fault-tolerance invariants.  Returns a ``ChaosReport``; every broken
    invariant is a ``violations`` entry (empty == pass)."""
    import os

    from repro.core.options import CompressOptions
    from repro.runtime import archive_io
    from repro.stream import FaultTolerance, RetryPolicy, stream_compress

    report = ChaosReport(scenario=scenario, violations=[])
    opts = CompressOptions(tau=tau, chunk_hyperblocks=chunk_hyperblocks)
    batch = comp.compress(hyperblocks, options=opts)
    batch_sections = [archive_io.pack_chunk_section(c) for c in batch.chunks]

    # explicit FaultTolerance/ChaosInjector objects (custom backoff + spec
    # rates) override the CompressOptions-derived defaults
    ft = FaultTolerance(
        retry=RetryPolicy(max_retries=3, base_backoff_s=0.005,
                          max_backoff_s=0.05, seed=spec.seed),
        deadline_s=deadline_s, quarantine=True)

    outcomes = []
    t0 = time.perf_counter()
    for run_i in range(2):                      # two runs: determinism check
        path = f"{out_path}.run{run_i}"
        chaos = ChaosInjector(spec)
        finished, result = _run_with_watchdog(
            lambda: stream_compress(
                comp, hyperblocks, options=opts, out_path=path,
                fault_tolerance=ft, chaos=chaos),
            budget_s)
        if not finished:
            report.violations.append(
                f"run {run_i}: DEADLOCK — no result within {budget_s}s")
            report.wall_s = time.perf_counter() - t0
            return report
        if isinstance(result, BaseException):
            # an aborted run is legal only if it left a salvageable partial
            if os.path.exists(path):
                report.violations.append(
                    f"run {run_i}: raised {result!r} but finalized {path}")
            try:
                with open(path + ".partial", "rb") as f:
                    archive_io.deserialize_archive(f.read(), strict=False)
            except Exception as e:   # retry-boundary: any failure is a viol.
                report.violations.append(
                    f"run {run_i}: aborted ({result!r}) without a "
                    f"salvageable partial: {e!r}")
            outcomes.append(("aborted", repr(result)))
            continue
        outcomes.append(("finalized", tuple(result.stats.retry_events),
                         tuple(result.quarantined)))
        report.retries = result.stats.total_retries()
        report.deadline_hits = sum(result.stats.deadline_hits.values())
        report.quarantined = len(result.quarantined)

        # finalized container: strict-readable, every chunk either
        # byte-identical to batch or a lossless verbatim fallback
        try:
            disk = archive_io.read_archive(path, strict=True)
        except Exception as e:   # retry-boundary: any failure is a violation
            report.violations.append(
                f"run {run_i}: finalized container unreadable: {e!r}")
            continue
        for ci, chunk in enumerate(disk.chunks):
            sec = archive_io.pack_chunk_section(chunk)
            if ci in result.quarantined:
                if not chunk.verbatim_blob:
                    report.violations.append(
                        f"run {run_i}: chunk {ci} quarantined but not "
                        f"flagged verbatim on disk")
                    continue
                start, n_hb = chunk.hb_start, chunk.n_hyperblocks
                decoded = comp.decode_stripe_verbatim(chunk)
                if not np.array_equal(
                        decoded, hyperblocks[start:start + n_hb]):
                    report.violations.append(
                        f"run {run_i}: verbatim chunk {ci} is not lossless")
            elif sec != batch_sections[ci]:
                report.violations.append(
                    f"run {run_i}: chunk {ci} differs from batch encoding "
                    f"without being quarantined")
        # end-to-end: the decoded field honors tau everywhere
        recon = comp.decompress(disk)
        d_gae = comp.cfg.gae_block_elems or comp.cfg.block_elems
        errs = np.linalg.norm(
            (hyperblocks - recon).reshape(-1, d_gae), axis=1)
        if float(errs.max()) > tau * (1 + 1e-5):
            report.violations.append(
                f"run {run_i}: tau guarantee violated after chaos "
                f"(max l2 {errs.max():.6g} > {tau})")
    report.wall_s = time.perf_counter() - t0
    # abandoned watchdog attempts may still be inside native (XLA) code;
    # let them land before interpreter teardown, else the process dies with
    # SIGABRT ("terminate called without an active exception") on exit
    for t in threading.enumerate():
        if t.daemon and t is not threading.current_thread() \
                and t.name.startswith(("stream-", "chaos-")):
            t.join(timeout=10.0)
    if len(outcomes) == 2 and outcomes[0] != outcomes[1]:
        report.violations.append(
            f"nondeterministic outcome for seed {spec.seed}: "
            f"{outcomes[0]!r} != {outcomes[1]!r}")
    return report


def _make_test_compressor(seed: int = 0):
    """A small fitted-enough compressor (random init + fitted PCA basis —
    no training) plus a matching dataset; mirrors the unit-test fixtures so
    the CLI gate runs in seconds."""
    import jax

    from repro.core import bae as bae_mod
    from repro.core import hbae as hbae_mod
    from repro.core.pipeline import CompressorConfig, HierarchicalCompressor

    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32,
                           hb_latent=8, bae_hidden=32, bae_latent=4,
                           gae_block_elems=80, hb_bin=0.01, bae_bin=0.01,
                           gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(seed))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(seed)
    hb = rng.standard_normal((28, cfg.k, cfg.block_elems)).astype(np.float32)
    hb *= 0.1
    comp.fit_basis(hb)
    return comp, hb


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        description="live chaos gate: stream-compress under injected "
                    "faults and assert the fault-tolerance invariants")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tau", type=float, default=0.5)
    ap.add_argument("--out", default="", help="scratch path for containers "
                    "(default: a temp dir)")
    args = ap.parse_args(argv)

    import os
    import tempfile
    tmpdir = None
    out = args.out
    if not out:
        tmpdir = tempfile.mkdtemp(prefix="chaos_")
        out = os.path.join(tmpdir, "chaos.rba")

    comp, hb = _make_test_compressor(args.seed)
    scenarios = [
        ("transient-storm", ChaosSpec(seed=args.seed, transient_rate=0.35),
         None),
        ("poison-stripes", ChaosSpec(seed=args.seed, transient_rate=0.1,
                                     permanent_rate=0.25), None),
        ("stage-hangs", ChaosSpec(seed=args.seed, hang_rate=0.3,
                                  hang_s=0.6), 0.15),
    ]
    failures = 0
    for name, spec, deadline in scenarios:
        report = run_chaos_check(
            comp, hb, args.tau, spec, f"{out}.{name}", scenario=name,
            deadline_s=deadline)
        print(report.summary())
        if name == "transient-storm" and report.quarantined:
            print(f"  VIOLATION: transient-only chaos quarantined "
                  f"{report.quarantined} chunks (retries should absorb)")
            failures += 1
        if not report.ok:
            failures += 1
    if failures:
        print(f"FAIL: {failures} chaos scenario(s) violated invariants",
              file=sys.stderr)
        return 1
    print("OK: all chaos scenarios honored the fault-tolerance invariants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
