"""Checkpointing: atomic, hashed, retained, async, elastic-reshardable.

Layout on disk (one directory per step)::

    <dir>/step_00000042/
        manifest.json      # per-tensor path, shape, dtype, sha256, file
        arrays.npz         # logical (unsharded) arrays
    <dir>/step_00000042.tmp.<pid>   # in-flight save (renamed on completion)

Design points for the 1000+-node posture (DESIGN.md §7):

* **Atomicity** — writes go to a tmp directory, fsync'd, then ``os.replace``d
  into place; a crash mid-save leaves only a tmp dir that restore ignores.
* **Integrity** — every tensor is sha256-hashed in the manifest; restore
  re-hashes and falls back to the previous complete step on any mismatch
  (torn/corrupt saves tolerated).
* **Retention** — keep the newest ``retention`` steps, delete older ones
  after a successful save.
* **Async** — ``save`` can hand off to a background thread so the train loop
  never blocks on the filesystem; ``wait()`` joins in-flight saves.
* **Elasticity** — arrays are stored *logically* (fully replicated numpy);
  ``restore(mesh=..., shardings=...)`` lays them out onto ANY device count /
  mesh shape, so a job restarted with fewer or more healthy hosts resumes
  from the same checkpoint (tested 8 -> 4 -> 8 devices).
* **Compression** — optional error-bounded compressed checkpoints using the
  paper's own blockwise PCA-GAE + quantize/entropy bitstream; restore
  guarantees per-block l2 error <= tau (see ``save_compressed``).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> tuple[list[tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for entry in path:
            if hasattr(entry, "key"):
                keys.append(str(entry.key))
            elif hasattr(entry, "idx"):
                keys.append(str(entry.idx))
            else:
                keys.append(str(entry))
        out.append((_SEP.join(keys), np.asarray(jax.device_get(leaf))))
    return out, treedef


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, retention: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.retention = retention
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    steps.append(int(name[len("step_"):]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, blocking: Optional[bool] = None,
             extra: Optional[dict] = None) -> None:
        """Checkpoint ``tree`` at ``step``. Device arrays are fetched before
        any thread handoff so the caller may donate/mutate them afterwards."""
        self.wait()
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise RuntimeError("previous async checkpoint save failed") from err
        leaves, treedef = _flatten_with_paths(tree)
        treedef_blob = pickle.dumps(treedef)
        blocking = (not self.async_save) if blocking is None else blocking
        if blocking:
            self._write(step, leaves, treedef_blob, extra)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, leaves, treedef_blob,
                                                  extra), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write_guarded(self, *args) -> None:
        try:
            self._write(*args)
        except BaseException as e:          # surfaced on the next save()
            self._save_error = e

    def _write(self, step: int, leaves, treedef_blob: bytes,
               extra: Optional[dict]) -> None:
        final = self._step_dir(step)
        tmp = f"{final}.tmp.{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "format": "npz-v1",
                    "extra": extra or {}, "tensors": []}
        buf = io.BytesIO()
        np.savez(buf, **{f"t{i}": arr for i, (_, arr) in enumerate(leaves)})
        for i, (path, arr) in enumerate(leaves):
            manifest["tensors"].append({
                "path": path, "key": f"t{i}", "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": _sha(arr)})
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(buf.getvalue())
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            f.write(treedef_blob)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._enforce_retention()

    def _enforce_retention(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.retention] if self.retention else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, step: Optional[int] = None, *, mesh=None, shardings=None
                ) -> tuple[int, PyTree]:
        """Restore the given (or newest valid) step.

        ``shardings``: optional pytree of NamedSharding/PartitionSpec matching
        the saved tree — arrays are ``device_put`` onto them (elastic restore
        onto any mesh).  Corrupt steps are skipped with fallback.
        """
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                tree = self._read(s)
            except Exception as e:          # torn/corrupt -> try previous
                last_err = e
                continue
            if shardings is not None:
                if mesh is not None:
                    from jax.sharding import NamedSharding, PartitionSpec
                    shardings = jax.tree.map(
                        lambda sp: NamedSharding(mesh, sp)
                        if isinstance(sp, PartitionSpec) else sp, shardings,
                        is_leaf=lambda sp: isinstance(sp, PartitionSpec))
                tree = jax.tree.map(lambda arr, sh: jax.device_put(arr, sh),
                                    tree, shardings)
            return s, tree
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.dir!r}") from last_err

    def _read(self, step: int) -> PyTree:
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(d, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = []
        for t in manifest["tensors"]:
            arr = data[t["key"]]
            if _sha(arr) != t["sha256"]:
                raise IOError(f"hash mismatch for {t['path']} at step {step}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# error-bounded compressed checkpoints (the paper's technique on weights)
# ---------------------------------------------------------------------------

def save_compressed(path: str, tree: PyTree, *, tau: float,
                    bin_size: float = 1e-4, block: int = 256,
                    min_size: int = 4096) -> dict:
    """Write an error-bounded compressed checkpoint.

    Every float tensor with >= ``min_size`` elements is blocked into
    ``block``-long vectors and encoded with the paper's PCA-GAE machinery
    (basis from the tensor's own blocks, top-M quantized coefficients per
    block, Huffman + index-bitmask bitstream) such that every block satisfies
    ||x - x^G||_2 <= tau on restore.  Small / non-float tensors are stored
    raw.  Returns size accounting {raw_bytes, compressed_bytes, ratio}.
    """
    from repro.core import entropy, gae

    leaves, treedef = _flatten_with_paths(tree)
    payload: dict[str, Any] = {"treedef": pickle.dumps(treedef), "tensors": []}
    raw_bytes = comp_bytes = 0
    for tpath, arr in leaves:
        raw_bytes += arr.nbytes
        entry: dict[str, Any] = {"path": tpath, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
        if arr.dtype.kind != "f" or arr.size < min_size:
            entry["kind"] = "raw"
            entry["blob"] = arr.tobytes()
            comp_bytes += len(entry["blob"])
        else:
            flat = arr.astype(np.float32).reshape(-1)
            pad = -flat.size % block
            blocks = np.pad(flat, (0, pad)).reshape(-1, block)
            basis = np.asarray(gae.fit_pca_basis(jnp.asarray(blocks)))
            zeros = np.zeros_like(blocks)
            _, codes = gae.gae_encode_blocks(blocks, zeros, basis, tau, bin_size)
            coeffs = (np.concatenate([c.qcoeffs[np.argsort(c.indices)]
                                      for c in codes])
                      if codes else np.zeros(0, np.int64))
            streams = entropy.huffman_compress(coeffs) if coeffs.size else None
            idx_blob = entropy.encode_index_sets(
                [np.sort(c.indices) for c in codes], block)
            binexp_blob = entropy.zlib_pack(
                np.asarray([c.bin_exp for c in codes], np.uint8).tobytes())
            gae_cost = (basis.nbytes + (streams.nbytes() if streams else 0)
                        + len(idx_blob) + len(binexp_blob))
            if gae_cost >= arr.nbytes:
                # incompressible tensor (flat residual spectrum): store raw —
                # the guarantee is then exactness, never pay for expansion
                entry["kind"] = "raw"
                entry["blob"] = arr.tobytes()
                comp_bytes += arr.nbytes
            else:
                entry.update(kind="gae", block=block, pad=pad, tau=tau,
                             bin_size=bin_size, basis=basis.tobytes(),
                             coeff_stream=streams, index_blob=idx_blob,
                             binexp_blob=binexp_blob)
                comp_bytes += gae_cost
        payload["tensors"].append(entry)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        pickle.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return {"raw_bytes": raw_bytes, "compressed_bytes": comp_bytes,
            "ratio": raw_bytes / max(comp_bytes, 1)}


def restore_compressed(path: str) -> PyTree:
    from repro.core import entropy, gae

    with open(path, "rb") as f:
        payload = pickle.load(f)
    treedef = pickle.loads(payload["treedef"])
    leaves = []
    for entry in payload["tensors"]:
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        if entry["kind"] == "raw":
            leaves.append(np.frombuffer(entry["blob"], dtype).reshape(shape))
            continue
        block = entry["block"]
        basis = np.frombuffer(entry["basis"], np.float32).reshape(block, block)
        index_sets = entropy.decode_index_sets(entry["index_blob"])
        binexps = np.frombuffer(entropy.zlib_unpack(entry["binexp_blob"]),
                                np.uint8)
        coeffs = (entropy.huffman_decompress(entry["coeff_stream"])
                  if entry["coeff_stream"] is not None else np.zeros(0, np.int64))
        pos = 0
        codes = []
        for i, idx in enumerate(index_sets):
            codes.append(gae.GAEBlockCode(m=idx.size, indices=idx,
                                          qcoeffs=coeffs[pos:pos + idx.size],
                                          bin_exp=int(binexps[i])))
            pos += idx.size
        n_blocks = len(codes)
        recon = gae.gae_decode_blocks(np.zeros((n_blocks, block), np.float32),
                                      basis, codes, entry["bin_size"])
        flat = recon.reshape(-1)
        if entry["pad"]:
            flat = flat[:-entry["pad"]]
        leaves.append(flat.astype(dtype).reshape(shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)
