"""Failure handling & straggler posture for long-running jobs (DESIGN.md §7).

``ResilientRunner`` wraps a step function with the recovery loop a 1000-node
deployment needs:

  * **crash/device-loss recovery** — any exception from the step (including
    injected ``SimulatedDeviceFailure``) triggers: reload newest valid
    checkpoint, rebuild the data iterator at that exact step (the token
    pipeline is deterministic-by-step), resume; bounded retries.
  * **NaN / loss-spike anomalies** — pluggable policy: ``"skip"`` drops the
    batch and moves on (grad already discarded), ``"restore"`` treats it like
    a crash and rolls back.
  * **preemption hook** — ``request_preemption()`` (wire it to SIGTERM in the
    launcher) checkpoints at the next step boundary and exits cleanly.
  * **straggler watchdog** — per-step wall-clock EMA; steps slower than
    ``watchdog_factor``x the EMA are counted and surfaced in stats (on real
    fleets this feeds the scheduler's replace-node signal; here it is the
    observable hook + test point).  Synchronous SPMD absorbs transient
    stragglers at the collective; the data pipeline keeps prefetch >= 2 so
    host hiccups don't stall the device stream.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.checkpoint import CheckpointManager


class SimulatedDeviceFailure(RuntimeError):
    """Raised by tests / chaos hooks to emulate losing a worker."""


@dataclasses.dataclass
class RunnerStats:
    steps: int = 0
    restores: int = 0
    skipped_batches: int = 0
    slow_steps: int = 0
    last_loss: float = float("nan")
    step_time_ema: float = 0.0


class ResilientRunner:
    """step_fn(state, batch) -> (state, metrics dict with 'loss')."""

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 make_data_iter: Callable[[int], Iterator],
                 *, save_every: int = 50, max_retries: int = 3,
                 anomaly_policy: str = "skip", loss_spike_factor: float = 10.0,
                 watchdog_factor: float = 3.0,
                 on_event: Optional[Callable[[str, dict], None]] = None):
        assert anomaly_policy in ("skip", "restore")
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.make_data_iter = make_data_iter
        self.save_every = save_every
        self.max_retries = max_retries
        self.anomaly_policy = anomaly_policy
        self.loss_spike_factor = loss_spike_factor
        self.watchdog_factor = watchdog_factor
        self.on_event = on_event or (lambda kind, info: None)
        self.stats = RunnerStats()
        self._preempted = False
        self._loss_ema: Optional[float] = None

    # -- hooks ------------------------------------------------------------
    def request_preemption(self) -> None:
        """Wire to SIGTERM: checkpoint at the next boundary and stop."""
        self._preempted = True

    # -- recovery ----------------------------------------------------------
    def _restore(self, fallback_state) -> tuple[int, Any]:
        try:
            step, state = self.ckpt.restore()
            self.stats.restores += 1
            self.on_event("restore", {"step": step})
            return step, state
        except FileNotFoundError:
            self.stats.restores += 1
            self.on_event("restore", {"step": 0, "cold": True})
            return 0, fallback_state

    def _anomalous(self, loss: float) -> bool:
        if not math.isfinite(loss):
            return True
        if self._loss_ema is None:
            return False
        return loss > self.loss_spike_factor * max(self._loss_ema, 1e-8)

    # -- main loop -----------------------------------------------------------
    def run(self, state: Any, start_step: int, num_steps: int) -> tuple[Any, int]:
        step = start_step
        data = self.make_data_iter(step)
        retries = 0
        end = start_step + num_steps
        while step < end and not self._preempted:
            t0 = time.monotonic()
            try:
                # next(data) INSIDE the recovery try: a crashing data
                # iterator (e.g. a prefetch worker death propagated by
                # PrefetchIterator) counts as a step failure and goes
                # through restore + iterator rebuild, not up the stack.
                batch = next(data)
                new_state, metrics = self.step_fn(state, batch)
                loss = float(jax.device_get(metrics["loss"]))
            except StopIteration:                        # exhausted, not failed
                raise
            except Exception as e:                       # crash / device loss
                retries += 1
                self.on_event("failure", {"step": step, "error": repr(e),
                                          "retry": retries})
                if retries > self.max_retries:
                    raise
                step, state = self._restore(state)
                data = self.make_data_iter(step)
                continue
            retries = 0

            if self._anomalous(loss):
                self.on_event("anomaly", {"step": step, "loss": loss})
                if self.anomaly_policy == "skip":
                    self.stats.skipped_batches += 1
                    step += 1                            # drop batch, keep state
                    continue
                step, state = self._restore(state)
                data = self.make_data_iter(step)
                continue

            dt = time.monotonic() - t0
            ema = self.stats.step_time_ema
            if ema > 0 and dt > self.watchdog_factor * ema:
                self.stats.slow_steps += 1
                self.on_event("straggler", {"step": step, "dt": dt, "ema": ema})
            self.stats.step_time_ema = dt if ema == 0 else 0.9 * ema + 0.1 * dt

            state = new_state
            self._loss_ema = (loss if self._loss_ema is None
                              else 0.9 * self._loss_ema + 0.1 * loss)
            self.stats.last_loss = loss
            self.stats.steps += 1
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state)
        if self._preempted:
            self.ckpt.save(step, state, blocking=True)
            self.on_event("preempted", {"step": step})
        self.ckpt.wait()
        return state, step


def chaos_wrap(step_fn: Callable, fail_at_steps: set[int]) -> Callable:
    """Test helper: make step_fn raise SimulatedDeviceFailure at given steps
    (once each)."""
    remaining = set(fail_at_steps)
    counter = {"n": 0}

    def wrapped(state, batch):
        n = counter["n"]
        counter["n"] += 1
        if n in remaining:
            remaining.discard(n)
            raise SimulatedDeviceFailure(f"injected failure at call {n}")
        return step_fn(state, batch)

    return wrapped
