"""Corruption injector + containment checker for the archive container.

Four corruption models, mirroring how storage actually fails:

* ``bit_flip``    — 1..8 random bit flips anywhere in the file (media decay)
* ``truncate``    — file cut at a random point (torn write / partial upload)
* ``zero_chunk``  — a random span zeroed (lost disk sector / hole punch)
* ``header_fuzz`` — random bytes splatted over the header + section table

``check_containment`` drives seeded corruptions through the reader and
asserts the contract the tests and the smoke gate rely on: every corruption is
either *detected* (typed ``ArchiveError``) or *survived* (tolerant read
returns an archive whose damage is confined to reported chunks).  Any other
exception — raw ``struct.error``, ``zlib.error``, ``IndexError`` — is an
escape and fails the run.

CLI (used by scripts/smoke.sh)::

    python -m repro.runtime.faultinject /tmp/a.rba --trials 40 --seed 0
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Callable, Optional

import numpy as np

from repro.core.errors import ArchiveError
from repro.runtime import archive_io

CORRUPTION_KINDS = ("bit_flip", "truncate", "zero_chunk", "header_fuzz")


def corrupt(data: bytes, kind: str, rng: np.random.Generator) -> bytes:
    """Return a corrupted copy of ``data`` under the given failure model."""
    buf = bytearray(data)
    if kind == "bit_flip":
        for _ in range(int(rng.integers(1, 9))):
            pos = int(rng.integers(0, len(buf)))
            buf[pos] ^= 1 << int(rng.integers(0, 8))
    elif kind == "truncate":
        buf = buf[:int(rng.integers(0, len(buf)))]
    elif kind == "zero_chunk":
        span = int(rng.integers(16, 513))
        pos = int(rng.integers(0, max(1, len(buf) - span)))
        buf[pos:pos + span] = b"\x00" * min(span, len(buf) - pos)
    elif kind == "header_fuzz":
        head = min(len(buf), archive_io._PROLOGUE.size + 256)
        for _ in range(int(rng.integers(1, 17))):
            pos = int(rng.integers(0, head))
            buf[pos] = int(rng.integers(0, 256))
    else:
        raise ValueError(f"unknown corruption kind {kind!r}")
    return bytes(buf)


@dataclasses.dataclass
class Trial:
    kind: str
    outcome: str          # "detected" | "survived" | "noop" | "escaped"
    detail: str = ""


@dataclasses.dataclass
class FuzzResult:
    trials: list[Trial]

    @property
    def escapes(self) -> list[Trial]:
        return [t for t in self.trials if t.outcome == "escaped"]

    @property
    def ok(self) -> bool:
        return not self.escapes

    def summary(self) -> str:
        counts: dict[str, int] = {}
        for t in self.trials:
            key = f"{t.kind}:{t.outcome}"
            counts[key] = counts.get(key, 0) + 1
        lines = [f"{len(self.trials)} trials, {len(self.escapes)} escapes"]
        lines += [f"  {k}: {v}" for k, v in sorted(counts.items())]
        lines += [f"  ESCAPE {t.kind}: {t.detail}" for t in self.escapes]
        return "\n".join(lines)


def check_containment(data: bytes, *, trials: int = 32, seed: int = 0,
                      decode: Optional[Callable] = None) -> FuzzResult:
    """Run seeded corruptions of a valid container through both read modes.

    ``decode``: optional callable ``decode(archive) -> None`` that runs the
    model-side tolerant decompression (when a fitted compressor is on hand);
    it must not raise for a tolerantly-read archive.
    """
    out: list[Trial] = []
    for t in range(trials):
        rng = np.random.default_rng(seed * 100003 + t)
        kind = CORRUPTION_KINDS[t % len(CORRUPTION_KINDS)]
        bad = corrupt(data, kind, rng)
        if bad == data:
            out.append(Trial(kind, "noop"))
            continue
        # strict mode: corruption must be detected with a typed error
        try:
            archive_io.deserialize_archive(bad, strict=True)
            # undetected change: only legal if it truly cannot alter decode
            out.append(Trial(kind, "escaped", "strict read accepted a "
                                              "modified container"))
            continue
        except ArchiveError as e:
            strict_detail = type(e).__name__
        except Exception as e:   # raw struct/zlib/index error leaked through
            out.append(Trial(kind, "escaped", f"strict: {e!r}"))
            continue
        # tolerant mode: must yield a damage-scoped archive or a typed error
        try:
            archive = archive_io.deserialize_archive(bad, strict=False)
            if decode is not None:
                decode(archive)
            out.append(Trial(kind, "survived",
                             f"{strict_detail}; "
                             f"{len(archive.chunk_errors)} chunks damaged"))
        except ArchiveError:
            out.append(Trial(kind, "detected", strict_detail))
        except Exception as e:
            out.append(Trial(kind, "escaped", f"tolerant: {e!r}"))
    return FuzzResult(trials=out)


def check_partial_containment(data: bytes, *, trials: int = 32, seed: int = 0,
                              decode: Optional[Callable] = None) -> FuzzResult:
    """Fuzz the streaming ``.partial`` salvage path.

    A ``<path>.partial`` left by an aborted ``StreamingArchiveWriter`` is not
    a valid container (placeholder table entries never verify), so the strict
    leg of ``check_containment`` does not apply.  The contract here is
    tolerant-read only: every corruption of a partial must yield either a
    damage-scoped archive (``survived``) or a typed ``ArchiveError``
    (``detected``) — never a raw ``struct``/``zlib``/``IndexError`` escape.
    """
    out: list[Trial] = []
    for t in range(trials):
        rng = np.random.default_rng(seed * 100003 + t)
        kind = CORRUPTION_KINDS[t % len(CORRUPTION_KINDS)]
        bad = corrupt(data, kind, rng) if t else data   # trial 0: as-is
        kind = kind if t else "as_left_on_disk"
        try:
            archive = archive_io.deserialize_archive(bad, strict=False)
            if decode is not None:
                decode(archive)
            out.append(Trial(kind, "survived",
                             f"{len(archive.chunk_errors)} chunks damaged"))
        except ArchiveError as e:
            out.append(Trial(kind, "detected", type(e).__name__))
        except Exception as e:
            out.append(Trial(kind, "escaped", f"tolerant: {e!r}"))
    return FuzzResult(trials=out)


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded corruption-fuzz a .rba archive container")
    ap.add_argument("archive", help="path to a valid .rba container "
                                    "(or a .partial with --partial)")
    ap.add_argument("--trials", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--partial", action="store_true",
                    help="treat the input as a streaming-writer .partial: "
                         "skip the strict-validity precheck and fuzz the "
                         "tolerant salvage path only")
    args = ap.parse_args(argv)
    try:
        with open(args.archive, "rb") as f:
            data = f.read()
        if not args.partial:
            # the corpus must start from a valid container
            archive_io.deserialize_archive(data, strict=True)
    except (OSError, ArchiveError) as e:
        print(f"error: {args.archive}: not a valid container: {e}",
              file=sys.stderr)
        return 2
    if args.partial:
        result = check_partial_containment(data, trials=args.trials,
                                           seed=args.seed)
    else:
        result = check_containment(data, trials=args.trials, seed=args.seed)
    print(result.summary())
    if not result.ok:
        print("FAIL: corruption escaped the typed-error contract",
              file=sys.stderr)
        return 1
    print("OK: every corruption detected or survived")
    return 0


if __name__ == "__main__":
    sys.exit(main())
