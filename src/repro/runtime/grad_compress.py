"""Gradient compression for data-parallel all-reduce — the paper's GAE insight
applied to the DP collective (DESIGN.md §2).

The GAE mechanism (project a residual onto a shared orthonormal basis, keep
the leading coefficients, quantize, and error-feed the tail) is *linear*, so
coefficients aggregate exactly across data-parallel workers:

    mean_i(U^T g_i) = U^T mean_i(g_i).

Every worker therefore all-reduces only a rank-M coefficient tensor instead of
the full gradient — a PowerSGD-class scheme, but with the paper's machinery:
a fixed shared orthonormal basis (deterministic QR of a seeded Gaussian, so
all workers build an identical U with zero communication), per-block leading-M
projection, optional uniform quantization of the coefficients, and per-worker
**error feedback** that re-injects the discarded tail into the next step's
gradient (keeping the compressed SGD unbiased in the long run).

Shapes: every float leaf of the gradient pytree is flattened and blocked into
``block``-length vectors (zero-padded); the coefficient tensor per leaf is
(n_blocks, rank).  Compression payload ratio ~ rank/block (plus 4-byte scale).

Two modes:
  * ``pca_ef``  — rank-M, quantized, error feedback (DP-aggregatable), with
    **adaptive basis refresh**: a fixed basis never transmits the gradient
    component orthogonal to its span, so the error-feedback buffer grows
    linearly (a real failure mode — property-tested).  Every
    ``refresh_every`` steps the basis is recomputed as the top-``rank``
    eigenvectors of the block covariance of (grad + error) — the paper's own
    distributed-PCA machinery (Sec. II-D adapted): the covariance is a single
    (block x block) psum across workers, so every worker derives an IDENTICAL
    basis and coefficients stay exactly aggregatable.
  * ``gae``     — tau-driven per-block M via the one-shot GAE selection; the
    realized per-block l2 distortion of the *local* gradient is <= tau.  The
    variable-length index sets make it a storage/offload format (checkpoint
    deltas, gradient logging) rather than an all-reduce payload; aggregation
    support is the fixed-rank mode above.

Everything is jit-compatible; ``axis_name`` switches the same code between
single-process and shard_map'd multi-worker execution.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.quantization import dequantize, quantize

PyTree = Any
Array = jax.Array


class GradCompressionState(NamedTuple):
    basis: Array          # (block, rank) shared orthonormal basis
    error: PyTree         # per-leaf error-feedback buffers (leaf-shaped, f32)
    step: Array


def make_basis(block: int, rank: int, seed: int = 17) -> Array:
    """Deterministic orthonormal (block, rank) basis — identical on every
    worker from the seed alone (no broadcast needed)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (block, max(rank, 1)),
                          jnp.float32)
    q, _ = jnp.linalg.qr(g)
    return q[:, :rank]


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def init_state(params: PyTree, *, block: int = 256, rank: int = 32,
               seed: int = 17) -> GradCompressionState:
    error = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if _is_float(p) else None,
        params)
    return GradCompressionState(basis=make_basis(block, rank, seed),
                                error=error, step=jnp.zeros((), jnp.int32))


def _blocked(x: Array, block: int) -> Array:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.size % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)


def _unblocked(blocks: Array, shape: tuple, dtype) -> Array:
    n = math.prod(shape)          # python-level: shape is static under jit
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def compress_update(grads: PyTree, state: GradCompressionState, *,
                    bin_size: float = 0.0,
                    axis_name: Optional[str] = None,
                    refresh_every: int = 50
                    ) -> tuple[PyTree, GradCompressionState, dict]:
    """Rank-M compressed-aggregate gradients with error feedback and adaptive
    basis refresh (module docstring).

    Returns (approx mean gradient, new state, stats).  Under ``axis_name``
    (shard_map / pmap axis) only the rank-M coefficients (and, on refresh
    steps, one block x block covariance) are ``pmean``ed — that reduction IS
    the compressed all-reduce; without it the function is the single-worker
    reference semantics.
    """
    block = state.basis.shape[0]      # block length is defined by the basis
    rank = state.basis.shape[1]

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)

    # ---- adaptive basis refresh (the paper's distributed PCA on gradients)
    if refresh_every:
        cov = jnp.zeros((block, block), jnp.float32)
        for g, e in zip(flat_g, flat_e):
            if g is None or not _is_float(g):
                continue
            hb = _blocked(g.astype(jnp.float32) +
                          (e if e is not None else 0.0), block)
            cov = cov + hb.T @ hb
        if axis_name is not None:
            cov = jax.lax.pmean(cov, axis_name)

        def refreshed(cov):
            _, vecs = jnp.linalg.eigh(cov)
            return vecs[:, ::-1][:, :rank]

        basis = jax.lax.cond(state.step % refresh_every == 0,
                             refreshed, lambda _: state.basis, cov)
    else:
        basis = state.basis

    comm_elems = jnp.zeros((), jnp.float32)
    raw_elems = jnp.zeros((), jnp.float32)

    def leaf(g, e):
        nonlocal comm_elems, raw_elems
        if g is None or not _is_float(g):
            return g, e
        h = g.astype(jnp.float32) + (e if e is not None else 0.0)
        hb = _blocked(h, block)                           # (nb, block)
        c = hb @ basis                                     # (nb, rank)
        if bin_size > 0:
            cq = dequantize(quantize(c, bin_size), bin_size)
        else:
            cq = c
        # local decompression (for error feedback) BEFORE aggregation
        recon_local = cq @ basis.T
        e_new = _unblocked(hb - recon_local, h.shape, jnp.float32)
        if axis_name is not None:
            cq = jax.lax.pmean(cq, axis_name)              # the compressed AR
        recon = cq @ basis.T
        ghat = _unblocked(recon, g.shape, g.dtype)
        comm_elems += cq.size
        raw_elems += g.size
        return ghat, e_new

    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    if refresh_every:   # amortized covariance-psum cost of the basis refresh
        comm_elems += block * block / refresh_every
    stats = {"comm_elems": comm_elems, "raw_elems": raw_elems,
             "compression": raw_elems.astype(jnp.float32) /
             jnp.maximum(comm_elems.astype(jnp.float32), 1.0)}
    return new_g, GradCompressionState(basis=basis, error=new_e,
                                       step=state.step + 1), stats


# ---------------------------------------------------------------------------
# tau-driven GAE mode (bounded per-block distortion; storage/offload format)
# ---------------------------------------------------------------------------

def gae_compress_grads(grads: PyTree, *, tau: float, bin_size: float = 1e-4,
                       block: int = 256) -> tuple[PyTree, dict]:
    """Per-block guaranteed ||g - g^G||_2 <= tau using the paper's one-shot
    selection (Algorithm 1, batched form).  Returns (bounded grads, stats)."""
    from repro.core.gae import fit_pca_basis, gae_select

    kept = jnp.zeros((), jnp.float32)
    total = jnp.zeros((), jnp.float32)

    def leaf(g):
        nonlocal kept, total
        if g is None or not _is_float(g):
            return g
        gb = _blocked(g, block)
        basis = fit_pca_basis(gb)
        sel = gae_select(gb, basis, tau, bin_size)
        kept += jnp.sum(sel.m)
        total += gb.size
        return _unblocked(sel.corrected, g.shape, g.dtype)

    out = jax.tree.map(leaf, grads)
    return out, {"kept_coeffs": kept, "total_elems": total,
                 "keep_frac": kept / jnp.maximum(total, 1.0)}
