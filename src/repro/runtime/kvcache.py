"""Paged KV-cache with error-bounded compression of frozen pages
(DESIGN.md §2): the paper's hyper-block + PCA-GAE machinery applied to the
serving-time KV cache.

A page is 16 consecutive tokens of one layer's K (or V) tensor — shape
(page, KV, hd), flattened to a vector.  Pages still inside the active tail
window stay uncompressed (they are being appended / are attention-hot); pages
older than the window are *frozen* and compressed:

  * all frozen pages of a layer form the "dataset"; a PCA basis over the page
    vectors is fit once per compression epoch (cheap: D = page*KV*hd per-group
    covariance, the same distributed-PCA trick as GAE);
  * each page keeps the minimal number of quantized leading coefficients such
    that ||page - page^G||_2 <= tau — a *guaranteed* bound on the KV
    perturbation entering attention;
  * coefficients are quantized ints + index sets, so the archive cost is the
    honest storage cost (Huffman/bitmask accounting available host-side).

``CompressedKVStore`` is the host-side container used by ``serve.engine``;
``compress_pages`` / ``decompress_pages`` are the jit-friendly batch paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import entropy, gae

Array = jax.Array

PAGE_TOKENS = 16


def paginate(kv: np.ndarray, page: int = PAGE_TOKENS) -> np.ndarray:
    """(B, S, KV, hd) -> (B, n_pages, page*KV*hd); S must divide into pages."""
    b, s, kvh, hd = kv.shape
    assert s % page == 0, (s, page)
    return kv.reshape(b, s // page, page * kvh * hd)


def unpaginate(pages: np.ndarray, kvh: int, hd: int,
               page: int = PAGE_TOKENS) -> np.ndarray:
    b, np_, d = pages.shape
    assert d == page * kvh * hd
    return pages.reshape(b, np_ * page, kvh, hd)


@dataclasses.dataclass
class CompressedKVStore:
    """Frozen-page archive for one layer's K or V stream."""
    basis: np.ndarray                 # (D, D)
    codes: list[gae.GAEBlockCode]
    n_pages: int
    page_shape: tuple                 # (page, KV, hd)
    tau: float
    bin_size: float
    dtype: np.dtype

    def nbytes(self) -> int:
        """Honest archive cost: quantized coefficients (Huffman) + index
        bitmasks + per-page bin exponents.  The basis is amortized across the
        whole serving session (all pages, all requests) like the paper
        amortizes model cost."""
        coeffs = np.concatenate([c.qcoeffs for c in self.codes]) \
            if self.codes else np.zeros(0, np.int64)
        total = entropy.huffman_size_bits(coeffs) // 8 if coeffs.size else 0
        total += len(entropy.encode_index_sets(
            [np.sort(c.indices) for c in self.codes], self.basis.shape[0]))
        total += len(self.codes)  # bin_exp bytes
        return total

    def raw_nbytes(self) -> int:
        d = int(np.prod(self.page_shape))
        return self.n_pages * d * self.dtype.itemsize


def compress_pages(pages: np.ndarray, *, tau: float, bin_size: float = 1e-3,
                   basis: Optional[np.ndarray] = None,
                   page_shape: tuple = (PAGE_TOKENS, 1, 64)
                   ) -> tuple[np.ndarray, CompressedKVStore]:
    """pages: (N, D) flattened frozen pages.  Returns (reconstruction with the
    per-page guarantee, archive)."""
    pages = np.asarray(pages, np.float32)
    if basis is None:
        basis = np.asarray(gae.fit_pca_basis(jnp.asarray(pages)))
    zeros = np.zeros_like(pages)
    recon, codes = gae.gae_encode_blocks(pages, zeros, basis, tau, bin_size)
    store = CompressedKVStore(basis=basis, codes=codes, n_pages=pages.shape[0],
                              page_shape=page_shape, tau=tau,
                              bin_size=bin_size, dtype=np.dtype(np.float32))
    return recon, store


def decompress_pages(store: CompressedKVStore) -> np.ndarray:
    d = store.basis.shape[0]
    zeros = np.zeros((store.n_pages, d), np.float32)
    return gae.gae_decode_blocks(zeros, store.basis, store.codes,
                                 store.bin_size)


# ---------------------------------------------------------------------------
# attention-error propagation bound
# ---------------------------------------------------------------------------

def attention_perturbation_bound(tau: float, page_elems: int,
                                 n_pages: int) -> float:
    """Worst-case l2 perturbation of the attention *input* (the concatenated
    KV) given per-page ||dK||_2 <= tau: sqrt(n_pages) * tau (pages are
    disjoint coordinates).  Normalized per element: tau / sqrt(page_elems)."""
    return float(np.sqrt(n_pages) * tau)


# ---------------------------------------------------------------------------
# jit-friendly bounded quantization path (in-graph, for decode-loop use)
# ---------------------------------------------------------------------------

def quantize_kv_bounded(kv: Array, tau_per_token: float) -> tuple[Array, dict]:
    """In-graph uniform KV quantization with a per-token l2 guarantee:
    bin = 2 * tau / sqrt(KV*hd) makes the worst-case per-token quantization
    error exactly tau (quantization_error_bound).  Used on the decode hot
    path where host-side PCA would stall the step."""
    from repro.core.quantization import quantize_dequantize
    d = kv.shape[-1] * kv.shape[-2]
    bin_size = 2.0 * tau_per_token / float(np.sqrt(d))
    out = quantize_dequantize(kv, bin_size)
    return out, {"bin_size": bin_size, "bits_estimate":
                 jnp.log2(jnp.maximum(jnp.max(jnp.abs(kv)) / bin_size, 1.0)) + 1}
