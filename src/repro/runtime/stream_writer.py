"""Appendable streaming writer for the ``.rba`` archive container.

``archive_io.write_archive`` serializes the whole ``Archive`` in memory and
writes it atomically — fine for batch, useless for streaming, where chunk i
should hit disk while chunk i+1 is still on the device.  This module writes
the SAME byte layout incrementally:

* The stripe tiling (``spans``) is known before any chunk is encoded, so the
  section count, section names, and therefore the exact header length
  (``archive_io.head_size``) are fixed up front.  The header region is
  reserved at offset 0 from the first write.
* Pending chunks get PLACEHOLDER table entries (offset=0, length=0,
  crc=0xFFFFFFFF, sha=zeros).  A placeholder can never verify — crc32 of the
  empty slice is 0 — so a tolerant ``read_archive(strict=False)`` of a
  partial file reports every not-yet-appended chunk as damaged and salvages
  every completed one.  That is the two-phase section table: phase one is
  the placeholder layout, phase two patches real offsets/digests in.
* ``append(i, chunk)`` may arrive out of order (the host codec pool finishes
  stripes in whatever order the scheduler drains them); a reorder buffer
  writes sections strictly in index order so payload offsets stay identical
  to ``serialize_archive``'s concatenation order.  After each in-order write
  the header is re-patched in place (a single small ``pwrite`` at offset 0)
  and re-digested, so the on-disk partial is salvageable after every append.
* ``finalize()`` re-patches the fully-populated header, fsyncs, and
  atomically renames ``<path>.partial`` → ``<path>``.  The final file is
  byte-identical to ``archive_io.serialize_archive`` of the same chunks.

Crash window: only the header patch itself is non-atomic (the payload region
is append-only).  The patch is one small contiguous write, and a partial that
dies mid-patch loses the whole table — everything else loses at most the
chunks that had not been appended yet.
"""
from __future__ import annotations

import hashlib
import os
import zlib
from typing import Optional

from repro.core import exec as exec_mod
from repro.core.errors import ArchiveError
from repro.core.pipeline import ArchiveChunk
from repro.runtime import archive_io

_PLACEHOLDER_CRC = 0xFFFFFFFF
_PLACEHOLDER_SHA = b"\x00" * 32


class WriterStateError(ArchiveError):
    """StreamingArchiveWriter used out of protocol (double append, append
    after finalize, finalize with missing chunks, ...)."""


class StreamingArchiveWriter:
    """Incremental ``.rba`` writer with a two-phase section table.

    Parameters mirror the ``Archive`` geometry fields; ``spans`` is the
    ``[(hb_start, n_hyperblocks), ...]`` stripe tiling from
    ``HierarchicalCompressor.stripe_spans`` and fixes the number of chunk
    sections up front.
    """

    def __init__(self, path: str, *, n_hyperblocks: int, n_values: int,
                 chunk_hyperblocks: int, gae_dim: int, spans: list,
                 fsync_every: bool = False):
        if not spans:
            raise WriterStateError("cannot stream an archive with no chunks")
        self.path = path
        self.partial_path = f"{path}.partial"
        self.spans = [(int(s), int(n)) for s, n in spans]
        self._fsync_every = fsync_every
        self._meta_blob = archive_io.build_meta_blob(
            n_hyperblocks=n_hyperblocks, n_values=n_values,
            chunk_hyperblocks=chunk_hyperblocks, gae_dim=gae_dim, spans=spans)
        names = ([archive_io._META_NAME]
                 + [archive_io.chunk_section_name(i)
                    for i in range(len(spans))])
        self._head_len = archive_io.head_size(names)
        # entry i+1 covers chunk i; entry 0 is meta (known immediately).
        self._entries: list = [
            (archive_io._META_NAME, 0, len(self._meta_blob),
             zlib.crc32(self._meta_blob),
             hashlib.sha256(self._meta_blob).digest())]
        self._entries += [(name, 0, 0, _PLACEHOLDER_CRC, _PLACEHOLDER_SHA)
                          for name in names[1:]]
        self._tail = len(self._meta_blob)   # payload-relative next offset
        self._next = 0                      # next chunk index to hit disk
        self._pending: dict[int, bytes] = {}
        self._seen: set[int] = set()
        self._finalized = False
        self._f = open(self.partial_path, "w+b")
        try:
            self._patch_head()
            self._f.seek(self._head_len)
            self._f.write(self._meta_blob)
            self._sync()
        except BaseException:
            self._f.close()
            raise

    # -- protocol ----------------------------------------------------------

    def append(self, index: int, chunk: ArchiveChunk) -> None:
        """Record chunk ``index``; sections reach disk strictly in index
        order (out-of-order arrivals wait in the reorder buffer).

        IDEMPOTENT under retry: re-appending an index with a byte-identical
        section is a no-op (it re-attempts the drain, so a sink stage retry
        after a transient disk error makes progress instead of tripping the
        double-append guard).  Re-appending an index with DIFFERENT bytes is
        still a protocol error.
        """
        self._check_open()
        if not 0 <= index < len(self.spans):
            raise WriterStateError(
                f"chunk index {index} outside [0, {len(self.spans)})")
        start, n_hb = self.spans[index]
        if chunk.hb_start != start or chunk.n_hyperblocks != n_hb:
            raise WriterStateError(
                f"chunk {index} covers [{chunk.hb_start}, "
                f"+{chunk.n_hyperblocks}], span table says [{start}, +{n_hb}]")
        blob = archive_io.pack_chunk_section(chunk)
        if index in self._seen:
            if index in self._pending:
                if self._pending[index] != blob:
                    raise WriterStateError(
                        f"chunk {index} appended twice with different bytes")
            else:   # already durable: identical re-append is a no-op
                ent = self._entries[1 + index]
                if ent[3] != zlib.crc32(blob) or \
                        ent[4] != hashlib.sha256(blob).digest():
                    raise WriterStateError(
                        f"chunk {index} appended twice with different bytes")
                return
        else:
            self._seen.add(index)
            self._pending[index] = blob
        exec_mod.counter_max("stream.writer_reorder_depth",
                             len(self._pending))
        self._drain()

    def _drain(self) -> None:
        """Flush the in-order prefix of the reorder buffer to disk.
        Resumable: each section is committed (entry patched, tail advanced,
        buffer popped) only after its bytes are fully written, so an
        ``OSError`` mid-write leaves the writer state consistent and a
        retried ``append`` re-attempts the same section at the same offset."""
        drained = 0
        while self._next in self._pending:
            blob = self._pending[self._next]
            self._f.seek(self._head_len + self._tail)
            self._f.write(blob)
            self._entries[1 + self._next] = (
                archive_io.chunk_section_name(self._next), self._tail,
                len(blob), zlib.crc32(blob), hashlib.sha256(blob).digest())
            self._pending.pop(self._next)
            self._tail += len(blob)
            self._next += 1
            drained += 1
        if drained:
            self._patch_head()
            self._sync()
            exec_mod.counter_add("stream.chunks_on_disk", drained)

    def appended(self) -> int:
        """Chunks accepted so far (on disk or in the reorder buffer)."""
        return len(self._seen)

    def finalize(self) -> int:
        """Patch the final header, fsync, atomically rename the partial to
        ``self.path``; returns total bytes written."""
        self._check_open()
        if self._next != len(self.spans):
            missing = sorted(set(range(len(self.spans))) - self._seen)
            raise WriterStateError(
                f"finalize with {len(self.spans) - self._next} chunks not on "
                f"disk (missing appends: {missing[:8]}...)" if missing else
                f"finalize while {len(self._pending)} chunks wait in the "
                f"reorder buffer")
        self._patch_head()
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        self._finalized = True
        os.replace(self.partial_path, self.path)
        dirname = os.path.dirname(os.path.abspath(self.path))
        try:
            dfd = os.open(dirname, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass
        return self._head_len + self._tail

    def abort(self) -> None:
        """Stop writing, KEEPING ``<path>.partial`` on disk — the partial is
        the crash artifact tolerant readers salvage from."""
        if not self._finalized and not self._f.closed:
            try:
                self._f.flush()
            except OSError:
                pass
            self._f.close()

    def __enter__(self) -> "StreamingArchiveWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None and not self._finalized:
            self.finalize()
        else:
            self.abort()

    # -- internals ---------------------------------------------------------

    def _check_open(self) -> None:
        if self._finalized:
            raise WriterStateError("writer already finalized")
        if self._f.closed:
            raise WriterStateError("writer already aborted")

    def _patch_head(self) -> None:
        head = archive_io.pack_head(self._entries)
        if len(head) != self._head_len:
            raise WriterStateError(
                f"header drifted: packed {len(head)} bytes, reserved "
                f"{self._head_len}")
        self._f.seek(0)
        self._f.write(head)

    def _sync(self) -> None:
        self._f.flush()
        if self._fsync_every:
            os.fsync(self._f.fileno())
