"""Batched serving engine: prefill + decode with continuous batching and an
error-bounded compressed-KV option (the paper's technique at serving time).

The engine drives any registered arch through its ``decode_step`` — the same
function the decode_32k / long_500k dry-run cells lower — so what is served
here is exactly what is proven to compile on the production meshes.

Features:
  * batched prefill (scan over prompt tokens, one jitted step);
  * greedy / temperature sampling, per-slot stop lengths;
  * **continuous batching**: a slot queue; finished slots are refilled from
    the pending-request queue without stopping the batch (the vLLM-style
    serving loop, minus paged attention which lives in runtime/kvcache);
  * **compressed KV** (``kv_tau``): after prefill, each slot's KV cache is
    passed through the bounded quantizer (runtime.kvcache) with a per-token
    l2 guarantee — decode then attends the compressed cache, trading bounded
    KV distortion for HBM footprint exactly as DESIGN.md §2 prescribes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn_mod
from repro.models.registry import get_model
from repro.runtime.kvcache import quantize_kv_bounded

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    max_new_tokens: int
    # modality frontend payloads (stubs per assignment): whisper requests
    # carry precomputed frame embeddings, VLM requests patch embeddings
    frontend: Optional[dict] = None   # e.g. {"frames": (n_frames, d)}


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray             # generated ids
    prompt_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, run: RunConfig, params: Any, *,
                 batch_size: int, max_len: int, temperature: float = 0.0,
                 kv_tau: Optional[float] = None, seed: int = 0):
        self.cfg, self.run, self.params = cfg, run, params
        self.batch = batch_size
        self.max_len = max_len
        self.temperature = temperature
        self.kv_tau = kv_tau
        self.api = get_model(cfg)
        self._key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, s: self.api.decode_step(p, cfg, run, t, s))
        self._prefill = jax.jit(self._prefill_impl)

    # -- prefill: scan decode_step over the prompt -------------------------
    def _prefill_impl(self, params, tokens: Array, state):
        def body(st, tok):
            logits, st = self.api.decode_step(params, self.cfg, self.run,
                                              tok[:, None], st)
            return st, logits[:, 0]
        state, logits = jax.lax.scan(body, state, tokens.T)
        return state, logits[-1]                      # last-position logits

    def _compress_kv(self, state):
        """Bounded in-graph KV compression of every KVCache leaf."""
        def visit(node):
            if isinstance(node, attn_mod.KVCache):
                k, _ = quantize_kv_bounded(node.k, self.kv_tau)
                v, _ = quantize_kv_bounded(node.v, self.kv_tau)
                return attn_mod.KVCache(k=k, v=v, pos=node.pos,
                                        window=node.window)
            return node
        return jax.tree.map(visit, state,
                            is_leaf=lambda n: isinstance(n, attn_mod.KVCache))

    def _sample(self, logits: Array) -> Array:
        logits = logits[..., :self.cfg.vocab]
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1) \
            .astype(jnp.int32)

    # -- batch generation ----------------------------------------------------
    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       frontend: Optional[dict] = None) -> np.ndarray:
        """Same-length batched generation. prompts: (B, S) -> (B, max_new).
        ``frontend``: batched modality payloads, e.g. {"frames": (B, F, D)}."""
        b, s = prompts.shape
        state = self.api.init_decode_state(
            self.params, self.cfg, self.run, b, self.max_len,
            **{k: jnp.asarray(v) for k, v in (frontend or {}).items()})
        state, logits = self._prefill(self.params, jnp.asarray(prompts), state)
        if self.kv_tau is not None:
            state = self._compress_kv(state)
        out = np.zeros((b, max_new), np.int32)
        tok = self._sample(logits)
        for t in range(max_new):
            out[:, t] = np.asarray(tok)
            logits, state = self._decode(self.params, tok[:, None], state)
            tok = self._sample(logits[:, 0])
        return out

    # -- continuous batching over a request queue -----------------------------
    def serve(self, requests: list[Request]) -> list[Completion]:
        """Continuous batching: fixed slot count, finished slots refilled.
        Prompts are left-truncated to the engine max_len budget."""
        pending = list(reversed(requests))          # pop() = FIFO
        slots: list[Optional[dict]] = [None] * self.batch
        done: list[Completion] = []

        def admit(i: int) -> None:
            if not pending:
                slots[i] = None
                return
            req = pending.pop()
            prompt = req.prompt[-self.max_len // 2:]
            state = self.api.init_decode_state(
                self.params, self.cfg, self.run, 1, self.max_len,
                **{k: jnp.asarray(v)[None] for k, v in
                   (req.frontend or {}).items()})
            state, logits = self._prefill(
                self.params, jnp.asarray(prompt[None, :]), state)
            if self.kv_tau is not None:
                state = self._compress_kv(state)
            slots[i] = {"req": req, "state": state, "out": [],
                        "tok": self._sample(logits)}

        for i in range(self.batch):
            admit(i)
        while any(s is not None for s in slots):
            for i, s in enumerate(slots):
                if s is None:
                    continue
                s["out"].append(int(np.asarray(s["tok"])[0]))
                if len(s["out"]) >= s["req"].max_new_tokens:
                    done.append(Completion(
                        rid=s["req"].rid,
                        tokens=np.asarray(s["out"], np.int32),
                        prompt_len=len(s["req"].prompt)))
                    admit(i)
                    continue
                logits, s["state"] = self._decode(
                    self.params, s["tok"][:, None], s["state"])
                s["tok"] = self._sample(logits[:, 0])
        return sorted(done, key=lambda c: c.rid)
