"""Streaming compression subsystem: pipelined stage scheduler with
device/host overlap plus an appendable archive writer.

``stream_compress`` runs the SAME per-stripe stages as the batch
``HierarchicalCompressor.compress`` — fused device front-end, GAE error-bound
coding, chunk entropy coding — but pipelined through a ``StreamScheduler``
so host coding of chunk *i* overlaps the device stage of chunk *i+1*, and
finished chunk sections stream to disk through
``repro.runtime.stream_writer.StreamingArchiveWriter`` as they complete.

See docs/STREAMING.md for the scheduler model and queue/backpressure
semantics.
"""
from repro.stream.compress import (FaultTolerance, StreamResult,
                                   stream_compress)
from repro.stream.scheduler import (RetryPolicy, StageSpec, StageGraph,
                                    StreamScheduler, StreamStats)

__all__ = ["FaultTolerance", "RetryPolicy", "StageSpec", "StageGraph",
           "StreamScheduler", "StreamStats", "StreamResult",
           "stream_compress"]
