"""Streaming compress: the batch stripe stages, pipelined.

Stage graph (one stripe = one archive chunk flows left to right)::

    dispatch ──▶ transfer ──▶ host_encode ──▶ sink
    (async jax    (device_get   (GAE bound +     (StreamingArchiveWriter
     front-end    per stripe,    entropy coding   .append, in-order
     enqueue)     double-        on the shared    reorder-buffered)
                  buffered)      codec pool)

* ``dispatch`` calls ``exec.run_compress_stage_async`` — jax dispatch is
  asynchronous, so the stage only enqueues device work.  The bounded queue to
  ``transfer`` (depth = ``queue_depth``) is what double-buffers the device:
  at most ``queue_depth + 1`` stripes of latents exist on device at once.
* ``transfer`` blocks on ``exec.fetch_compress_stage`` (the per-stripe
  ``device_get``), overlapping stripe *i*'s download with stripe *i+1*'s
  compute.
* ``host_encode`` rides the SHARED codec worker pool (``exec.pool_submit``)
  — the same threads ``map_parallel`` uses for batch chunk fan-out — and
  calls ``HierarchicalCompressor.encode_stripe_host``, the exact function
  the batch path calls on the exact same slices.  Chunk sections are
  therefore byte-identical to the batch path BY CONSTRUCTION.
* ``sink`` appends each finished chunk to the ``StreamingArchiveWriter``
  (chunk *i* can hit disk while chunk *i+2* is still on the device) and
  collects chunks for the returned in-memory ``Archive``.

On any stage failure the scheduler drains, the writer is aborted — leaving
``<out_path>.partial`` on disk for ``read_archive(strict=False)`` salvage —
and the lowest-index stage error is re-raised.

With a ``FaultTolerance`` policy the run instead degrades gracefully:
transient stage failures retry with seeded backoff, hung attempts are
abandoned at the stage deadline, and a stripe that permanently fails (or
raises ``GuaranteeUnsatisfiable``) is QUARANTINED — re-encoded as a lossless
verbatim fallback chunk, so the finalized archive still contains every
hyper-block within tau.  Quarantined chunk indices surface in
``StreamResult.quarantined`` / ``StreamStats``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import exec as exec_mod
from repro.core.errors import TransientStageError
from repro.core.options import CompressOptions, resolve_options
from repro.core.pipeline import Archive, ArchiveChunk, HierarchicalCompressor
from repro.runtime.stream_writer import StreamingArchiveWriter
from repro.stream.scheduler import RetryPolicy, StageGraph, StageSpec, \
    StreamScheduler, StreamStats

#: Sentinel distinguishing "kwarg not passed" from an explicit ``None`` on
#: the deprecated ``stream_compress(tau=..., ...)`` kwarg surface.
_UNSET = object()


@dataclasses.dataclass
class FaultTolerance:
    """Fault-tolerance posture for one streaming run.

    * ``retry`` applies per item to the dispatch/transfer/host_encode stages
      (and, with OSErrors classified transient, to the sink).
    * ``deadline_s`` arms the per-attempt watchdog on the compute stages
      (never the sink: an abandoned half-finished disk write racing its own
      retry is worse than blocking on it).
    * ``quarantine=True`` re-encodes a permanently-failed stripe as a
      lossless verbatim chunk instead of failing the run.
    """
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    deadline_s: Optional[float] = None
    quarantine: bool = True


class _Quarantined:
    """In-flight marker: this stripe permanently failed an upstream stage
    and rides the rest of the pipeline as a quarantine order."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class StreamResult:
    """What ``stream_compress`` hands back."""
    archive: Archive
    stats: StreamStats
    bytes_written: int = 0        # 0 when no out_path was given
    quarantined: list = dataclasses.field(default_factory=list)
    quarantine_reasons: dict = dataclasses.field(default_factory=dict)
    chaos_injected: dict = dataclasses.field(default_factory=dict)
    # ^ faults the injector actually fired, by kind (empty when no chaos)


def stream_compress(comp: HierarchicalCompressor, hyperblocks: np.ndarray,
                    tau=_UNSET, chunk_hyperblocks=_UNSET,
                    out_path: Optional[str] = None, *,
                    options: Optional[CompressOptions] = None,
                    queue_depth=_UNSET,
                    host_workers: Optional[int] = None,
                    fsync_every: bool = False,
                    fault_tolerance: Optional[FaultTolerance] = None,
                    chaos=None) -> StreamResult:
    """Pipelined compress of ``hyperblocks``; byte-identical chunks to
    ``comp.compress(hyperblocks, options=options)``.

    Configuration comes in as ONE ``repro.core.options.CompressOptions``
    (``options=...``); the old ``tau=``/``chunk_hyperblocks=``/
    ``queue_depth=`` kwargs remain as a deprecated shim.  ``out_path``,
    ``host_workers`` and ``fsync_every`` are IO concerns of THIS entry point,
    not compression semantics, so they stay plain kwargs.

    When ``out_path`` is given, finished chunk sections stream into
    ``<out_path>.partial`` as they complete and the container is atomically
    finalized to ``out_path`` on success; on failure the partial is kept for
    tolerant salvage.  Without ``out_path`` only the in-memory ``Archive`` is
    produced.

    Fault tolerance arms itself from the options (``retries`` /
    ``stage_deadline_s`` / ``chaos_seed`` — any one of them set enables the
    retry → deadline → quarantine ladder).  An explicit ``fault_tolerance=``
    / ``chaos=`` object overrides the options-derived default for callers
    that need a custom ``RetryPolicy`` or ``ChaosSpec``; permanently failing
    stripes are quarantined as lossless verbatim chunks so the run still
    finalizes with every hyper-block within tau.

    With ``options.mesh`` set, aligned runs of ``n_shards`` stripes ride the
    scheduler as ONE item each (= one ``shard_map`` call, one stripe per
    shard); the ragged tail stays per-stripe.  Chunk boundaries, chunk bytes
    and the on-disk container are identical to the single-device stream —
    per-shard block shapes equal per-stripe shapes, and the host entropy
    fan-out still consumes exactly one stripe per chunk (all shard-local).
    """
    legacy = {}
    if tau is not _UNSET:
        legacy["tau"] = tau
    if chunk_hyperblocks is not _UNSET:
        legacy["chunk_hyperblocks"] = chunk_hyperblocks
    if queue_depth is not _UNSET:
        legacy["queue_depth"] = queue_depth
    opts = resolve_options(options, legacy, caller="stream_compress")
    tau = opts.tau
    queue_depth = opts.queue_depth

    mesh = None
    if opts.mesh is not None:
        from repro.parallel import mesh_exec
        mesh = mesh_exec.resolve_mesh(opts.mesh)

    ft = fault_tolerance
    if ft is None and opts.fault_tolerant():
        ft = FaultTolerance(
            retry=RetryPolicy(
                max_retries=opts.retries if opts.retries is not None else 3,
                seed=opts.chaos_seed if opts.chaos_seed is not None else 0),
            deadline_s=opts.stage_deadline_s, quarantine=True)
    if chaos is None and opts.chaos_seed is not None:
        from repro.runtime.chaosinject import ChaosInjector, ChaosSpec
        chaos = ChaosInjector(ChaosSpec(seed=opts.chaos_seed,
                                        transient_rate=0.25,
                                        permanent_rate=0.05))

    cfg = comp.cfg
    n = hyperblocks.shape[0]
    gae_dim = comp.prepare_compress(hyperblocks, tau, mesh=mesh)
    spans = comp.stripe_spans(n, opts.chunk_hyperblocks,
                              with_gae=tau is not None)
    width = comp._chunk_width(opts.chunk_hyperblocks,
                              with_gae=tau is not None)
    chunks: list[Optional[ArchiveChunk]] = [None] * len(spans)
    quarantine_reasons: dict[int, str] = {}

    # Scheduler items: one entry per DEVICE DISPATCH, each a list of
    # (chunk_idx, span).  Unsharded: one stripe per item.  Sharded: aligned
    # groups of n_shards stripes collapse into one item (one shard_map call);
    # the ragged tail stays per-stripe.
    if mesh is not None:
        from repro.parallel import mesh_exec
        groups, tail_spans = mesh_exec.plan_shard_groups(
            spans, mesh_exec.mesh_shards(mesh))
        items: list[list] = []
        ci = 0
        for group in groups:
            items.append([(ci + j, span) for j, span in enumerate(group)])
            ci += len(group)
        for span in tail_spans:
            items.append([(ci, span)])
            ci += 1
    else:
        items = [[(ci, span)] for ci, span in enumerate(spans)]

    writer: Optional[StreamingArchiveWriter] = None
    if out_path is not None:
        writer = StreamingArchiveWriter(
            out_path, n_hyperblocks=n, n_values=hyperblocks.size,
            chunk_hyperblocks=width, gae_dim=gae_dim, spans=spans,
            fsync_every=fsync_every)

    def dispatch(i: int, item: list) -> tuple:
        if len(item) == 1:
            _, (start, n_hb) = item[0]
            handles = exec_mod.run_compress_stage_async(
                comp.hbae_params, comp._stage_params(),
                hyperblocks[start:start + n_hb], cfg.hb_bin, cfg.bae_bin)
        else:
            start = item[0][1][0]
            stop = item[-1][1][0] + item[-1][1][1]
            handles = exec_mod.run_compress_stage_sharded_async(
                comp.hbae_params, comp._stage_params(),
                hyperblocks[start:stop], cfg.hb_bin, cfg.bae_bin, mesh)
            exec_mod.counter_max("mesh.shards", len(item))
            exec_mod.counter_add("mesh.sharded_groups")
        return item, handles

    def transfer(i: int, payload) -> list:
        if isinstance(payload, _Quarantined):
            return payload                     # ride through to host_encode
        item, handles = payload
        q_lh, q_lbs, recon = exec_mod.fetch_compress_stage(handles)
        base = item[0][1][0]
        k = cfg.k
        parts = []
        for ci, (start, n_hb) in item:
            lo = start - base
            parts.append((ci, (start, n_hb),
                          (q_lh[lo:lo + n_hb],
                           [q[lo * k:(lo + n_hb) * k] for q in q_lbs],
                           recon[lo:lo + n_hb])))
        return parts

    def quarantine_encode(i: int, exc: BaseException) -> list:
        out = []
        for ci, (start, n_hb) in items[i]:
            quarantine_reasons[ci] = repr(exc)
            out.append((ci, comp.encode_stripe_verbatim(
                start, hyperblocks[start:start + n_hb])))
        return out

    def host_encode(i: int, payload) -> list:
        if isinstance(payload, _Quarantined):
            return quarantine_encode(i, payload.exc)
        # ride the shared codec pool — same workers as batch map_parallel;
        # a sharded item fans its stripes out across the pool concurrently
        futures = [(ci, exec_mod.pool_submit(
            comp.encode_stripe_host, start,
            hyperblocks[start:start + n_hb], q_lh, q_lbs, recon,
            tau, gae_dim))
            for ci, (start, n_hb), (q_lh, q_lbs, recon) in payload]
        return [(ci, f.result()) for ci, f in futures]

    def sink(i: int, encoded: list) -> int:
        for ci, chunk in encoded:
            chunks[ci] = chunk
            if writer is not None:
                try:
                    writer.append(ci, chunk)
                except OSError as e:
                    # transient disk errors ride the retry ladder; append is
                    # idempotent under retry (byte-identical re-append), so a
                    # multi-chunk item replays already-durable chunks safely
                    raise TransientStageError(
                        f"sink append of chunk {ci} failed: {e}") from e
        return i

    retry = ft.retry if ft is not None else None
    deadline = ft.deadline_s if ft is not None else None
    fallback = (lambda i, payload, exc: _Quarantined(exc)) \
        if ft is not None and ft.quarantine else None
    encode_fallback = (lambda i, payload, exc: quarantine_encode(i, exc)) \
        if ft is not None and ft.quarantine else None

    workers = host_workers if host_workers else exec_mod.codec_workers()
    graph = StageGraph([
        StageSpec("dispatch", dispatch, workers=1, queue_depth=queue_depth,
                  retry=retry, deadline_s=deadline, fallback=fallback),
        StageSpec("transfer", transfer, workers=1, queue_depth=queue_depth,
                  retry=retry, deadline_s=deadline, fallback=fallback),
        StageSpec("host_encode", host_encode, workers=max(1, workers),
                  queue_depth=max(queue_depth, workers),
                  retry=retry, deadline_s=deadline,
                  fallback=encode_fallback),
        StageSpec("sink", sink, workers=1, queue_depth=1, retry=retry),
    ])

    bytes_written = 0
    try:
        _, stats = StreamScheduler(graph, chaos=chaos).run(items)
    except BaseException:      # retry-boundary: abort the writer, re-raise
        if writer is not None:
            writer.abort()     # keep <out_path>.partial for tolerant salvage
        raise
    if writer is not None:
        bytes_written = writer.finalize()

    archive = Archive(n_hyperblocks=n, n_values=hyperblocks.size,
                      chunk_hyperblocks=width, gae_dim=gae_dim, chunks=chunks)
    quarantined = archive.verbatim_chunks()
    stats.quarantined = list(quarantined)
    if quarantined:
        exec_mod.counter_add("stream.quarantined_chunks", len(quarantined))
    return StreamResult(archive=archive, stats=stats,
                        bytes_written=bytes_written,
                        quarantined=quarantined,
                        quarantine_reasons=dict(quarantine_reasons),
                        chaos_injected=(dict(chaos.injected)
                                        if chaos is not None else {}))
