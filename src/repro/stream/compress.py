"""Streaming compress: the batch stripe stages, pipelined.

Stage graph (one stripe = one archive chunk flows left to right)::

    dispatch ──▶ transfer ──▶ host_encode ──▶ sink
    (async jax    (device_get   (GAE bound +     (StreamingArchiveWriter
     front-end    per stripe,    entropy coding   .append, in-order
     enqueue)     double-        on the shared    reorder-buffered)
                  buffered)      codec pool)

* ``dispatch`` calls ``exec.run_compress_stage_async`` — jax dispatch is
  asynchronous, so the stage only enqueues device work.  The bounded queue to
  ``transfer`` (depth = ``queue_depth``) is what double-buffers the device:
  at most ``queue_depth + 1`` stripes of latents exist on device at once.
* ``transfer`` blocks on ``exec.fetch_compress_stage`` (the per-stripe
  ``device_get``), overlapping stripe *i*'s download with stripe *i+1*'s
  compute.
* ``host_encode`` rides the SHARED codec worker pool (``exec.pool_submit``)
  — the same threads ``map_parallel`` uses for batch chunk fan-out — and
  calls ``HierarchicalCompressor.encode_stripe_host``, the exact function
  the batch path calls on the exact same slices.  Chunk sections are
  therefore byte-identical to the batch path BY CONSTRUCTION.
* ``sink`` appends each finished chunk to the ``StreamingArchiveWriter``
  (chunk *i* can hit disk while chunk *i+2* is still on the device) and
  collects chunks for the returned in-memory ``Archive``.

On any stage failure the scheduler drains, the writer is aborted — leaving
``<out_path>.partial`` on disk for ``read_archive(strict=False)`` salvage —
and the lowest-index stage error is re-raised.

With a ``FaultTolerance`` policy the run instead degrades gracefully:
transient stage failures retry with seeded backoff, hung attempts are
abandoned at the stage deadline, and a stripe that permanently fails (or
raises ``GuaranteeUnsatisfiable``) is QUARANTINED — re-encoded as a lossless
verbatim fallback chunk, so the finalized archive still contains every
hyper-block within tau.  Quarantined chunk indices surface in
``StreamResult.quarantined`` / ``StreamStats``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import exec as exec_mod
from repro.core.errors import TransientStageError
from repro.core.pipeline import Archive, ArchiveChunk, HierarchicalCompressor
from repro.runtime.stream_writer import StreamingArchiveWriter
from repro.stream.scheduler import RetryPolicy, StageGraph, StageSpec, \
    StreamScheduler, StreamStats


@dataclasses.dataclass
class FaultTolerance:
    """Fault-tolerance posture for one streaming run.

    * ``retry`` applies per item to the dispatch/transfer/host_encode stages
      (and, with OSErrors classified transient, to the sink).
    * ``deadline_s`` arms the per-attempt watchdog on the compute stages
      (never the sink: an abandoned half-finished disk write racing its own
      retry is worse than blocking on it).
    * ``quarantine=True`` re-encodes a permanently-failed stripe as a
      lossless verbatim chunk instead of failing the run.
    """
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    deadline_s: Optional[float] = None
    quarantine: bool = True


class _Quarantined:
    """In-flight marker: this stripe permanently failed an upstream stage
    and rides the rest of the pipeline as a quarantine order."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class StreamResult:
    """What ``stream_compress`` hands back."""
    archive: Archive
    stats: StreamStats
    bytes_written: int = 0        # 0 when no out_path was given
    quarantined: list = dataclasses.field(default_factory=list)
    quarantine_reasons: dict = dataclasses.field(default_factory=dict)


def stream_compress(comp: HierarchicalCompressor, hyperblocks: np.ndarray,
                    tau: Optional[float] = None, chunk_hyperblocks: int = 64,
                    out_path: Optional[str] = None, *, queue_depth: int = 2,
                    host_workers: Optional[int] = None,
                    fsync_every: bool = False,
                    fault_tolerance: Optional[FaultTolerance] = None,
                    chaos=None) -> StreamResult:
    """Pipelined compress of ``hyperblocks``; byte-identical chunks to
    ``comp.compress(hyperblocks, tau, chunk_hyperblocks)``.

    When ``out_path`` is given, finished chunk sections stream into
    ``<out_path>.partial`` as they complete and the container is atomically
    finalized to ``out_path`` on success; on failure the partial is kept for
    tolerant salvage.  Without ``out_path`` only the in-memory ``Archive`` is
    produced.

    ``fault_tolerance=None`` keeps the historical fail-fast semantics (any
    stage error aborts the run).  With a ``FaultTolerance``, transient
    failures retry, hung attempts hit the stage deadline, and permanently
    failing stripes are quarantined as lossless verbatim chunks (when
    ``quarantine`` is enabled) so the run still finalizes with every
    hyper-block within tau.  ``chaos`` is a fault injector forwarded to the
    scheduler (``repro.runtime.chaosinject``).
    """
    cfg = comp.cfg
    n = hyperblocks.shape[0]
    gae_dim = comp.prepare_compress(hyperblocks, tau)
    spans = comp.stripe_spans(n, chunk_hyperblocks, with_gae=tau is not None)
    width = comp._chunk_width(chunk_hyperblocks, with_gae=tau is not None)
    chunks: list[Optional[ArchiveChunk]] = [None] * len(spans)
    quarantine_reasons: dict[int, str] = {}

    writer: Optional[StreamingArchiveWriter] = None
    if out_path is not None:
        writer = StreamingArchiveWriter(
            out_path, n_hyperblocks=n, n_values=hyperblocks.size,
            chunk_hyperblocks=width, gae_dim=gae_dim, spans=spans,
            fsync_every=fsync_every)

    def dispatch(i: int, span: tuple) -> tuple:
        start, n_hb = span
        handles = exec_mod.run_compress_stage_async(
            comp.hbae_params, comp._stage_params(),
            hyperblocks[start:start + n_hb], cfg.hb_bin, cfg.bae_bin)
        return span, handles

    def transfer(i: int, payload) -> tuple:
        if isinstance(payload, _Quarantined):
            return payload                     # ride through to host_encode
        span, handles = payload
        return span, exec_mod.fetch_compress_stage(handles)

    def quarantine_encode(i: int, exc: BaseException) -> ArchiveChunk:
        start, n_hb = spans[i]
        quarantine_reasons[i] = repr(exc)
        return comp.encode_stripe_verbatim(
            start, hyperblocks[start:start + n_hb])

    def host_encode(i: int, payload) -> ArchiveChunk:
        if isinstance(payload, _Quarantined):
            return quarantine_encode(i, payload.exc)
        (start, n_hb), (q_lh, q_lbs, recon) = payload
        # ride the shared codec pool — same workers as batch map_parallel
        return exec_mod.pool_submit(
            comp.encode_stripe_host, start,
            hyperblocks[start:start + n_hb], q_lh, q_lbs, recon,
            tau, gae_dim).result()

    def sink(i: int, chunk: ArchiveChunk) -> int:
        chunks[i] = chunk
        if writer is not None:
            try:
                writer.append(i, chunk)
            except OSError as e:
                # transient disk errors ride the retry ladder; append is
                # idempotent under retry (byte-identical re-append)
                raise TransientStageError(
                    f"sink append of chunk {i} failed: {e}") from e
        return i

    ft = fault_tolerance
    retry = ft.retry if ft is not None else None
    deadline = ft.deadline_s if ft is not None else None
    fallback = (lambda i, payload, exc: _Quarantined(exc)) \
        if ft is not None and ft.quarantine else None
    encode_fallback = (lambda i, payload, exc: quarantine_encode(i, exc)) \
        if ft is not None and ft.quarantine else None

    workers = host_workers if host_workers else exec_mod.codec_workers()
    graph = StageGraph([
        StageSpec("dispatch", dispatch, workers=1, queue_depth=queue_depth,
                  retry=retry, deadline_s=deadline, fallback=fallback),
        StageSpec("transfer", transfer, workers=1, queue_depth=queue_depth,
                  retry=retry, deadline_s=deadline, fallback=fallback),
        StageSpec("host_encode", host_encode, workers=max(1, workers),
                  queue_depth=max(queue_depth, workers),
                  retry=retry, deadline_s=deadline,
                  fallback=encode_fallback),
        StageSpec("sink", sink, workers=1, queue_depth=1, retry=retry),
    ])

    bytes_written = 0
    try:
        _, stats = StreamScheduler(graph, chaos=chaos).run(spans)
    except BaseException:      # retry-boundary: abort the writer, re-raise
        if writer is not None:
            writer.abort()     # keep <out_path>.partial for tolerant salvage
        raise
    if writer is not None:
        bytes_written = writer.finalize()

    archive = Archive(n_hyperblocks=n, n_values=hyperblocks.size,
                      chunk_hyperblocks=width, gae_dim=gae_dim, chunks=chunks)
    quarantined = archive.verbatim_chunks()
    stats.quarantined = list(quarantined)
    if quarantined:
        exec_mod.counter_add("stream.quarantined_chunks", len(quarantined))
    return StreamResult(archive=archive, stats=stats,
                        bytes_written=bytes_written,
                        quarantined=quarantined,
                        quarantine_reasons=dict(quarantine_reasons))
