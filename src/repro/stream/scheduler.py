"""Pipelined stage scheduler: bounded queues, backpressure, overlap metering.

A ``StageGraph`` is a linear chain of ``StageSpec``s.  ``StreamScheduler``
runs every input item through every stage on dedicated worker threads, with a
bounded ``queue.Queue`` between consecutive stages:

* **Backpressure** — ``queue_depth`` caps how many finished results of stage
  *s* may wait for stage *s+1*.  A full queue blocks stage *s*'s workers (and
  ultimately the feeder), so a slow host coder throttles device dispatch
  instead of accumulating unbounded device buffers.  Depth 1 between the
  dispatch and transfer stages is classic double-buffering: one stripe being
  fetched while at most ``depth + 1`` are in flight behind it.
* **Ordered feed, unordered completion** — items enter stage 0 in index
  order; stages with several workers may finish out of order.  Results are
  collected by index, so downstream consumers (the streaming archive writer)
  see a deterministic mapping regardless of thread scheduling.
* **Deterministic failures** — a stage raising on item *j* records the error
  and drops *j* from the pipeline; every other item still runs to completion
  (no short-circuit racing).  After the drain, the scheduler raises the error
  of the LOWEST failing index — the same exception a serial loop would have
  raised — so streaming failures are reproducible in tests.
* **Shutdown** — the feeder appends one sentinel per stage-0 worker; the last
  worker of each stage to see its sentinel forwards sentinels downstream, so
  every thread exits even on partial failure.

Overlap metering: a shared ``_BusyTracker`` integrates wall time over the
run, attributing each interval by how many DISTINCT stages had a busy worker
— ``busy_s`` (>= 1 stage active) and ``overlap_s`` (>= 2 stages active, i.e.
genuine device/host overlap, measured, not inferred).  Per-stage busy time
feeds ``exec.record_stage("stream.<name>", ...)`` and queue high-water marks
feed ``exec.counter_max``, so ``exec.stats_summary()`` shows the whole
picture next to the batch counters.

Fault tolerance (the retry → failover ladder, per item, per stage):

* **Retry** — a ``StageSpec`` with a ``RetryPolicy`` re-runs an attempt that
  raised a transient error (``TransientStageError``, which includes deadline
  hits) with seeded exponential backoff + jitter.  Backoff delays are a pure
  function of ``(policy.seed, stage, item, attempt)``, so the retry timeline
  is reproducible run to run — the determinism the chaos harness asserts.
* **Deadline** — a ``deadline_s`` stage runs each attempt on a disposable
  watchdog thread and abandons it past the deadline
  (``StageDeadlineExceeded``).  The worker keeps draining its queue, so a
  hung attempt can never deadlock the bounded queues; the abandoned call
  finishes (or not) on a daemon thread whose result is discarded.
* **Failover** — when retries are exhausted (or the error is permanent) a
  stage's ``fallback(index, payload, exc)`` may substitute a result and keep
  the item alive (the compress pipeline uses this to quarantine a poison
  stripe into a lossless verbatim chunk).  Without a fallback the item is
  dropped and its error re-raised after the drain, exactly as before.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import zlib
from typing import Any, Callable, Optional, Sequence

from repro.core import exec as exec_mod
from repro.core.errors import StageDeadlineExceeded, TransientStageError

_SENTINEL = object()


def _unit_hash(*parts) -> float:
    """Deterministic (cross-process, hash-seed independent) uniform in
    [0, 1) from the given parts — the seeded jitter source.

    crc32 alone has poor avalanche on near-identical strings (draws for
    adjacent items land within ~1% of each other), so a murmur-style 32-bit
    finalizer decorrelates the bits before normalizing."""
    h = zlib.crc32("|".join(map(str, parts)).encode())
    h = ((h ^ (h >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    h = ((h ^ (h >> 16)) * 0x45D9F3B) & 0xFFFFFFFF
    return (h ^ (h >> 16)) / 2.0 ** 32


@dataclasses.dataclass
class RetryPolicy:
    """Per-item, per-stage retry schedule for transient failures.

    ``delay(stage, item, attempt)`` is a pure function of the policy seed and
    the coordinates — same seed, same failure pattern => same retry timeline,
    which is what makes chaos runs reproducible.
    """
    max_retries: int = 3
    base_backoff_s: float = 0.01
    max_backoff_s: float = 1.0
    jitter: float = 0.25              # +[0, jitter) fraction on top of base
    seed: int = 0
    retryable: Optional[Callable[[BaseException], bool]] = None

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.base_backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff seconds must be >= 0")

    def is_transient(self, exc: BaseException) -> bool:
        if self.retryable is not None:
            return bool(self.retryable(exc))
        return isinstance(exc, TransientStageError)

    def delay(self, stage: str, item: int, attempt: int) -> float:
        base = min(self.max_backoff_s, self.base_backoff_s * (2 ** attempt))
        u = _unit_hash("backoff", self.seed, stage, item, attempt)
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass
class StageSpec:
    """One pipeline stage.

    ``fn(index, payload) -> result``; the result is the next stage's payload.
    ``workers`` threads run the stage concurrently; ``queue_depth`` bounds the
    stage's INPUT queue — how many upstream results may wait for this stage
    before the upstream workers (or the feeder, for stage 0) block.

    Fault-tolerance knobs (all optional; defaults keep the pre-existing
    fail-fast semantics):

    * ``retry`` — retry transient failures per ``RetryPolicy``.
    * ``deadline_s`` — per-attempt watchdog; a hung attempt is abandoned and
      surfaces as ``StageDeadlineExceeded`` (transient, so retryable).
    * ``fallback(index, payload, exc)`` — called when an item permanently
      fails this stage; its return value is forwarded downstream in place of
      the stage result.  If the fallback itself raises, the item is dropped
      and that error is recorded.
    """
    name: str
    fn: Callable[[int, Any], Any]
    workers: int = 1
    queue_depth: int = 2
    retry: Optional[RetryPolicy] = None
    deadline_s: Optional[float] = None
    fallback: Optional[Callable[[int, Any, BaseException], Any]] = None

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError(f"stage {self.name!r}: workers must be >= 1")
        if self.queue_depth < 1:
            raise ValueError(f"stage {self.name!r}: queue_depth must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"stage {self.name!r}: deadline_s must be > 0")


class StageGraph:
    """A linear chain of stages (the only topology the compress path needs;
    fan-out lives inside a stage via the shared codec pool)."""

    def __init__(self, stages: Sequence[StageSpec]):
        if not stages:
            raise ValueError("StageGraph needs at least one stage")
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        self.stages = list(stages)


@dataclasses.dataclass
class StreamStats:
    """Measured pipeline behavior for one ``run``."""
    n_items: int = 0
    wall_s: float = 0.0
    busy_s: float = 0.0      # wall time with >= 1 stage busy
    overlap_s: float = 0.0   # wall time with >= 2 distinct stages busy
    stage_busy_s: dict = dataclasses.field(default_factory=dict)
    queue_high_water: dict = dataclasses.field(default_factory=dict)
    # fault-tolerance accounting (empty on a clean run)
    retries: dict = dataclasses.field(default_factory=dict)        # per stage
    deadline_hits: dict = dataclasses.field(default_factory=dict)  # per stage
    failovers: dict = dataclasses.field(default_factory=dict)      # per stage
    retry_events: list = dataclasses.field(default_factory=list)
    #   ^ [(stage, item, attempt, delay_s), ...] — the deterministic timeline
    quarantined: list = dataclasses.field(default_factory=list)
    #   ^ item indices whose shipped result is a fallback (set by the
    #     compress pipeline, which knows what a fallback result means)

    def overlap_efficiency(self) -> float:
        """Fraction of the wall clock during which at least two pipeline
        stages were simultaneously busy (1.0 = perfectly overlapped)."""
        return self.overlap_s / self.wall_s if self.wall_s > 0 else 0.0

    def total_retries(self) -> int:
        return sum(self.retries.values())


class _BusyTracker:
    """Integrates wall time by the number of distinct busy stages."""

    def __init__(self):
        self._lock = threading.Lock()
        self._active: dict[str, int] = {}
        self._last = time.perf_counter()
        self.busy_s = 0.0
        self.overlap_s = 0.0

    def _advance(self) -> None:
        now = time.perf_counter()
        dt = now - self._last
        self._last = now
        distinct = sum(1 for v in self._active.values() if v > 0)
        if distinct >= 1:
            self.busy_s += dt
        if distinct >= 2:
            self.overlap_s += dt

    def enter(self, name: str) -> None:
        with self._lock:
            self._advance()
            self._active[name] = self._active.get(name, 0) + 1

    def exit(self, name: str) -> None:
        with self._lock:
            self._advance()
            self._active[name] -= 1


class StreamScheduler:
    """Runs items through a ``StageGraph`` with bounded inter-stage queues.

    ``chaos`` (optional) is a fault injector consulted before every attempt:
    ``chaos.before(stage_name, item_index, attempt)`` may raise (injected
    transient/permanent fault) or sleep (injected hang — covered by the
    stage deadline because the call runs inside the watchdog thread).  See
    ``repro.runtime.chaosinject``.
    """

    def __init__(self, graph: StageGraph, *, chaos=None):
        self.graph = graph
        self.chaos = chaos

    def _attempt(self, spec: StageSpec, idx: int, payload, attempt: int):
        """Run one attempt of ``spec.fn`` (chaos hook included), abandoning
        it past ``spec.deadline_s`` on a disposable watchdog thread."""
        chaos = self.chaos

        def invoke():
            if chaos is not None:
                chaos.before(spec.name, idx, attempt)
            return spec.fn(idx, payload)

        if spec.deadline_s is None:
            return invoke()
        box: dict = {}
        done = threading.Event()

        def guarded():
            try:
                box["result"] = invoke()
            except BaseException as e:   # retry-boundary: re-raised below
                box["error"] = e
            finally:
                done.set()

        t = threading.Thread(target=guarded, daemon=True,
                             name=f"stream-{spec.name}-attempt-{idx}")
        t.start()
        if not done.wait(spec.deadline_s):
            # the attempt keeps running on its daemon thread; its boxed
            # result (if it ever arrives) is never read again
            raise StageDeadlineExceeded(spec.name, idx, spec.deadline_s)
        if "error" in box:
            raise box["error"]
        return box["result"]

    def run(self, items: Sequence) -> tuple[list, StreamStats]:
        """Push every item through the pipeline; returns ``(results, stats)``
        with ``results[i]`` = last stage's output for item ``i``.

        Raises the lowest-index stage error after ALL other items have
        drained (deterministic regardless of worker scheduling); the partial
        results of non-failing items are discarded by the raise, but their
        side effects (e.g. archive-writer appends) have already happened.
        """
        stages = self.graph.stages
        items = list(items)
        stats = StreamStats(n_items=len(items))
        if not items:
            return [], stats

        # queues[s] feeds stage s; the feeder owns queues[0].
        queues: list[queue.Queue] = [
            queue.Queue(maxsize=max(1, spec.queue_depth))
            for spec in stages]
        results: dict[int, Any] = {}
        errors: dict[int, BaseException] = {}
        stage_busy: dict[str, float] = {s.name: 0.0 for s in stages}
        high_water: dict[str, int] = {s.name: 0 for s in stages}
        remaining = [s.workers for s in stages]   # workers yet to shut down
        lock = threading.Lock()
        busy = _BusyTracker()

        def process(spec: StageSpec, idx: int, payload) -> tuple[bool, Any]:
            """Retry → failover ladder for one item; returns (ok, result).
            On ``ok=False`` the error has been recorded and the item drops
            out of the pipeline."""
            attempt = 0
            while True:
                try:
                    return True, self._attempt(spec, idx, payload, attempt)
                except BaseException as e:   # retry-boundary: ladder below
                    if isinstance(e, StageDeadlineExceeded):
                        with lock:
                            stats.deadline_hits[spec.name] = \
                                stats.deadline_hits.get(spec.name, 0) + 1
                    policy = spec.retry
                    if (policy is not None and policy.is_transient(e)
                            and attempt < policy.max_retries):
                        delay = policy.delay(spec.name, idx, attempt)
                        with lock:
                            stats.retries[spec.name] = \
                                stats.retries.get(spec.name, 0) + 1
                            stats.retry_events.append(
                                (spec.name, idx, attempt, round(delay, 9)))
                        time.sleep(delay)
                        attempt += 1
                        continue
                    if spec.fallback is not None:
                        try:
                            result = spec.fallback(idx, payload, e)
                        except BaseException as e2:   # retry-boundary
                            with lock:
                                errors[idx] = e2
                            return False, None
                        with lock:
                            stats.failovers[spec.name] = \
                                stats.failovers.get(spec.name, 0) + 1
                        return True, result
                    with lock:
                        errors[idx] = e
                    return False, None

        def worker(si: int) -> None:
            spec = stages[si]
            in_q = queues[si]
            out_q = queues[si + 1] if si + 1 < len(stages) else None
            while True:
                with lock:
                    depth = in_q.qsize()
                    if depth > high_water[spec.name]:
                        high_water[spec.name] = depth
                task = in_q.get()
                if task is _SENTINEL:
                    break
                idx, payload = task
                t0 = time.perf_counter()
                busy.enter(spec.name)
                try:
                    ok, result = process(spec, idx, payload)
                    if ok:
                        if out_q is not None:
                            out_q.put((idx, result))
                        else:
                            with lock:
                                results[idx] = result
                finally:
                    busy.exit(spec.name)
                    dt = time.perf_counter() - t0
                    with lock:
                        stage_busy[spec.name] += dt
            # last worker out forwards shutdown downstream
            with lock:
                remaining[si] -= 1
                last = remaining[si] == 0
            if last and si + 1 < len(stages):
                for _ in range(stages[si + 1].workers):
                    queues[si + 1].put(_SENTINEL)

        t_start = time.perf_counter()
        threads = [threading.Thread(target=worker, args=(si,),
                                    name=f"stream-{spec.name}-{w}",
                                    daemon=True)
                   for si, spec in enumerate(stages)
                   for w in range(spec.workers)]
        for t in threads:
            t.start()
        for i, item in enumerate(items):
            queues[0].put((i, item))          # blocks when stage 0 backs up
        for _ in range(stages[0].workers):
            queues[0].put(_SENTINEL)
        for t in threads:
            t.join()
        stats.wall_s = time.perf_counter() - t_start
        # the per-(stage, item) retry timeline is deterministic; the GLOBAL
        # append order is thread-interleaving noise — canonicalize it so
        # same-seed runs compare equal (the chaos determinism invariant)
        stats.retry_events.sort()
        stats.busy_s = busy.busy_s
        stats.overlap_s = busy.overlap_s
        stats.stage_busy_s = dict(stage_busy)
        stats.queue_high_water = dict(high_water)

        # fold into the global exec counters so launch/compress.py and the
        # benchmarks surface pipeline behavior via exec.stats_summary()
        for name, seconds in stage_busy.items():
            exec_mod.record_stage(f"stream.{name}", seconds, calls=1)
        for name, depth in high_water.items():
            exec_mod.counter_max(f"stream.queue_high_water.{name}", depth)
        exec_mod.counter_add("stream.overlap_s", stats.overlap_s)
        exec_mod.counter_add("stream.busy_s", stats.busy_s)
        exec_mod.counter_max("stream.overlap_efficiency",
                             round(stats.overlap_efficiency(), 4))
        if stats.retries:
            exec_mod.counter_add("stream.retries", stats.total_retries())
        for name, hits in stats.deadline_hits.items():
            exec_mod.counter_add(f"stream.deadline_hits.{name}", hits)
        for name, n in stats.failovers.items():
            exec_mod.counter_add(f"stream.failovers.{name}", n)

        if errors:
            raise errors[min(errors)]
        return [results[i] for i in range(len(items))], stats
