from repro.train import optim  # noqa: F401
