"""LM training loop: jitted train step with DP/TP shardings, gradient-
accumulation microbatching, remat, and optional compressed gradient
aggregation (the paper's technique on the DP collective).

``make_train_step`` builds the pjit-able step for any registered arch; the
same function lowers on 1 CPU device (smoke tests), the 256-chip pod, and the
512-chip multi-pod mesh — only the shardings differ (launch/dryrun.py).

TrainState is a flat NamedTuple so shardings can be expressed per-field; the
optimizer state shards exactly like the params (ZeRO-equivalent under GSPMD).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.registry import get_model
from repro.runtime import grad_compress
from repro.train import optim

PyTree = Any
Array = jax.Array


class TrainState(NamedTuple):
    params: PyTree
    opt: optim.AdamState
    gc: Optional[grad_compress.GradCompressionState]
    step: Array


def init_train_state(key, cfg: ModelConfig, run: RunConfig,
                     optimizer: optim.Optimizer) -> TrainState:
    api = get_model(cfg)
    params = api.init_params(key, cfg, run)
    if run.param_dtype != "float32":
        from repro.models.transformer import cast_params
        params = cast_params(params, jnp.dtype(run.param_dtype))
    gc = None
    if run.gradient_compression == "pca_ef":
        gc = grad_compress.init_state(params, rank=run.grad_comp_rank)
    return TrainState(params=params, opt=optimizer.init(params), gc=gc,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    optimizer: optim.Optimizer, *,
                    microbatches: int = 1,
                    axis_name: Optional[str] = None) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    ``microbatches`` > 1 splits the per-call batch along axis 0 and
    accumulates gradients in fp32 via lax.scan (sequential microbatching) —
    the standard trick to fit the global batch per step.
    """
    api = get_model(cfg)

    def loss_fn(params, batch):
        if run.cast_params_early and run.compute_dtype != run.param_dtype:
            from repro.models.transformer import cast_params
            params = cast_params(params, jnp.dtype(run.compute_dtype))
        return api.train_loss(params, cfg, run, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def compute_grads(params, batch):
        if microbatches == 1:
            return grad_fn(params, batch)
        b = jax.tree.leaves(batch)[0].shape[0]
        assert b % microbatches == 0, (b, microbatches)
        mb = jax.tree.map(
            lambda x: x.reshape(microbatches, b // microbatches, *x.shape[1:]),
            batch)

        def body(acc, m):
            loss, g = grad_fn(params, m)
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc[0], g), \
                acc[1] + loss
            return acc, ()

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zero, jnp.zeros(())), mb)
        inv = 1.0 / microbatches
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        loss, grads = compute_grads(state.params, batch)
        metrics = {"loss": loss}
        gc_state = state.gc
        if run.gradient_compression == "pca_ef":
            grads, gc_state, gc_stats = grad_compress.compress_update(
                grads, gc_state, axis_name=axis_name)
            metrics["grad_compression"] = gc_stats["compression"]
        elif run.gradient_compression == "gae":
            grads, gc_stats = grad_compress.gae_compress_grads(
                grads, tau=run.grad_comp_tau or 1e-3)
            metrics["grad_keep_frac"] = gc_stats["keep_frac"]
        params, opt, stats = optimizer.update(grads, state.opt, state.params)
        metrics.update(stats)
        return TrainState(params=params, opt=opt, gc=gc_state,
                          step=state.step + 1), metrics

    return step


def make_eval_step(cfg: ModelConfig, run: RunConfig) -> Callable:
    api = get_model(cfg)

    def step(params, batch):
        return {"loss": api.train_loss(params, cfg, run, batch)}

    return step
