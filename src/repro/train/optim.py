"""Optimizers and LR schedules (pure-JAX pytree implementation).

Shared by the compressor training loops (``repro.core.training``) and the LM
trainer (``repro.train.loop``).  The interface mirrors optax's
``init/update`` pair but is self-contained (optax is not available offline).

All state is a pytree shaped like the params, so it shards identically to the
params under GSPMD (ZeRO-style optimizer-state sharding comes for free when the
update step is jitted with sharded in/out shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), tree)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                           final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    warmup_steps = max(warmup_steps, 1)

    def sched(step: jax.Array) -> jax.Array:
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / warmup_steps
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def linear_decay_schedule(peak_lr: float, total_steps: int) -> Callable[[jax.Array], jax.Array]:
    def sched(step: jax.Array) -> jax.Array:
        t = jnp.clip(jnp.asarray(step, jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return peak_lr * (1.0 - t)
    return sched


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params) -> (new_params, state, stats)."""
    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, PyTree], tuple[PyTree, Any, dict]]


def adamw(lr: float | Callable[[jax.Array], jax.Array] = 1e-3,
          b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, max_grad_norm: Optional[float] = None,
          mu_dtype=jnp.float32) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params: PyTree) -> AdamState:
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=tree_zeros_like(params, mu_dtype),
                         nu=tree_zeros_like(params, jnp.float32))

    def update(grads: PyTree, state: AdamState, params: PyTree):
        stats = {}
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
            stats["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = sched(step)
        stats["lr"] = lr_t
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * delta
            return newp.astype(p.dtype), m.astype(mu_dtype), v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamState(step=step, mu=new_m, nu=new_v), stats

    return Optimizer(init=init, update=update)


def adam(lr=1e-3, **kw) -> Optimizer:
    """Paper setup: Adam, lr=1e-3 (Sec. III-C)."""
    return adamw(lr=lr, weight_decay=0.0, **kw)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0,
        max_grad_norm: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    class SgdState(NamedTuple):
        step: jax.Array
        mu: PyTree

    def init(params):
        return SgdState(jnp.zeros((), jnp.int32), tree_zeros_like(params))

    def update(grads, state, params):
        stats = {}
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
            stats["grad_norm"] = gnorm
        step = state.step + 1
        lr_t = sched(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state.mu, grads)
        newp = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr_t * m.astype(jnp.float32)).astype(p.dtype),
                            params, mu)
        return newp, SgdState(step, mu), stats

    return Optimizer(init=init, update=update)
