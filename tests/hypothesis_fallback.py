"""Use hypothesis when installed; otherwise skip property tests gracefully.

The offline CI image does not ship ``hypothesis``; importing it at module
scope used to fail collection for the whole file, taking the plain unit tests
down with it.  Import ``given``/``settings``/``st`` from here instead: with
hypothesis present they are the real thing, without it the ``@given`` tests
are skipped and everything else still runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: any strategy call returns
        None, which is fine because the decorated test never runs."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass  # pragma: no cover
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return decorate
