"""Durable archive container: round-trip, digests, chunk-scoped degradation,
fault-injection containment, and the pickle-free model manifest."""
from __future__ import annotations

import os
import pickle

import numpy as np
import pytest

from repro.core import (ArchiveError, ChecksumMismatch, CompressorConfig,
                        HierarchicalCompressor, MalformedStream,
                        TruncatedArchive)
from repro.runtime import archive_io, faultinject

TAU = 0.3
N_HB, K, D = 48, 2, 16
D_GAE = 16
GAE_PER_HB = (K * D) // D_GAE


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((N_HB, 1, D)).astype(np.float32)
    hb = (base + 0.1 * rng.standard_normal((N_HB, K, D))).astype(np.float32)
    cfg = CompressorConfig(block_elems=D, k=K, emb=8, hidden=16, hb_latent=6,
                           bae_latent=4, epochs_hbae=3, epochs_bae=2, batch=16,
                           hb_bin=0.02, bae_bin=0.02, gae_bin=0.02,
                           gae_block_elems=D_GAE)
    comp = HierarchicalCompressor(cfg).fit(hb, seed=0)
    archive = comp.compress(hb, tau=TAU, chunk_hyperblocks=16)
    return comp, hb, archive, archive_io.serialize_archive(archive)


def _block_errs(hb, recon):
    return np.linalg.norm((hb - recon).reshape(-1, D_GAE), axis=1)


def _intact_mask(report):
    mask = np.ones(N_HB * GAE_PER_HB, bool)
    for h in report.damaged_hyperblocks():
        mask[h * GAE_PER_HB:(h + 1) * GAE_PER_HB] = False
    return mask


# ---------------------------------------------------------------------------
# round-trip + accounting
# ---------------------------------------------------------------------------

def test_container_roundtrip_bitexact(fitted, tmp_path):
    comp, hb, archive, _ = fitted
    path = str(tmp_path / "a.rba")
    archive_io.write_archive(archive, path)
    back = archive_io.read_archive(path)
    np.testing.assert_array_equal(comp.decompress(back),
                                  comp.decompress(archive))
    assert _block_errs(hb, comp.decompress(back)).max() <= TAU * (1 + 1e-5)


def test_compressed_bytes_matches_disk(fitted, tmp_path):
    _, _, archive, blob = fitted
    path = str(tmp_path / "a.rba")
    written = archive_io.write_archive(archive, path)
    assert written == os.path.getsize(path) == len(blob)
    assert archive.compressed_bytes() == os.path.getsize(path)


def test_multi_chunk_striping(fitted):
    _, _, archive, _ = fitted
    assert len(archive.chunks) == 3          # 48 hyper-blocks / stripe 16
    assert [c.hb_start for c in archive.chunks] == [0, 16, 32]
    # every chunk decodes independently: its GAE section covers exactly its
    # own hyper-blocks' GAE blocks
    for c in archive.chunks:
        from repro.core import entropy
        sets = entropy.decode_index_sets(c.gae_index_blob,
                                         expect_dim=archive.gae_dim)
        assert len(sets) == c.n_hyperblocks * GAE_PER_HB


def test_tolerant_read_of_intact_archive_reports_clean(fitted):
    comp, _, _, blob = fitted
    archive = archive_io.deserialize_archive(blob, strict=False)
    recon, report = comp.decompress(archive, strict=False)
    assert report.ok and report.intact_fraction() == 1.0
    assert "intact" in report.summary()


# ---------------------------------------------------------------------------
# corruption: detected or survived, never a raw crash
# ---------------------------------------------------------------------------

def test_truncation_raises_typed(fitted):
    _, _, _, blob = fitted
    for cut in (0, 4, archive_io._PROLOGUE.size + 1, len(blob) // 2,
                len(blob) - 3):
        with pytest.raises(ArchiveError):
            archive_io.deserialize_archive(blob[:cut])


def test_bad_magic_and_version(fitted):
    _, _, _, blob = fitted
    with pytest.raises(MalformedStream):
        archive_io.deserialize_archive(b"NOTMAGIC" + blob[8:])
    bad_ver = blob[:8] + b"\xff\xff\xff\x7f" + blob[12:]
    with pytest.raises((MalformedStream, ChecksumMismatch)):
        archive_io.deserialize_archive(bad_ver)


def test_zero_chunk_graceful_degradation(fitted):
    comp, hb, _, blob = fitted
    # zero a span deep in the payload: damages some (not all) chunk sections
    pos = int(len(blob) * 0.6)
    bad = blob[:pos] + b"\x00" * 64 + blob[pos + 64:]
    with pytest.raises(ChecksumMismatch):
        archive_io.deserialize_archive(bad, strict=True)
    archive = archive_io.deserialize_archive(bad, strict=False)
    assert archive.chunk_errors                      # something was damaged
    recon, report = comp.decompress(archive, strict=False)
    assert not report.ok
    assert 0 < report.intact_fraction() < 1.0
    mask = _intact_mask(report)
    assert _block_errs(hb, recon)[mask].max() <= TAU * (1 + 1e-5)
    assert "damaged" in report.summary()


def test_strict_decompress_refuses_damaged_archive(fitted):
    comp, _, _, blob = fitted
    pos = int(len(blob) * 0.6)
    bad = blob[:pos] + b"\xff" * 16 + blob[pos + 16:]
    archive = archive_io.deserialize_archive(bad, strict=False)
    with pytest.raises(ArchiveError):
        comp.decompress(archive, strict=True)


def test_corruption_containment_property(fitted):
    """THE robustness invariant: for seeded bit-flips, truncations, zeroed
    spans and header fuzz, decode either raises a typed ArchiveError or
    returns a damage report under which every undamaged GAE block still meets
    the tau bound.  No raw struct/zlib/Index errors escape."""
    comp, hb, _, blob = fitted

    def decode(archive):
        recon, report = comp.decompress(archive, strict=False)
        mask = _intact_mask(report)
        if mask.any():
            assert _block_errs(hb, recon)[mask].max() <= TAU * (1 + 1e-5), \
                report.summary()

    result = faultinject.check_containment(blob, trials=48, seed=7,
                                           decode=decode)
    assert result.ok, result.summary()
    outcomes = {t.outcome for t in result.trials}
    assert "survived" in outcomes or "detected" in outcomes


def test_faultinject_cli(fitted, tmp_path, capsys):
    _, _, archive, _ = fitted
    path = str(tmp_path / "a.rba")
    archive_io.write_archive(archive, path)
    rc = faultinject.main([path, "--trials", "12", "--seed", "3"])
    assert rc == 0
    assert "OK" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# no pickle on the read path
# ---------------------------------------------------------------------------

def test_no_pickle_on_read_path(fitted, tmp_path, monkeypatch):
    comp, hb, archive, _ = fitted
    apath = str(tmp_path / "a.rba")
    mpath = str(tmp_path / "model.npz")
    archive_io.write_archive(archive, apath)
    comp.save(mpath)

    def boom(*a, **k):
        raise AssertionError("pickle used on the archive read path")

    monkeypatch.setattr(pickle, "load", boom)
    monkeypatch.setattr(pickle, "loads", boom)
    monkeypatch.setattr(pickle, "Unpickler", boom)
    back = archive_io.read_archive(apath)
    comp2 = HierarchicalCompressor.load(mpath)
    recon = comp2.decompress(back)
    assert _block_errs(hb, recon).max() <= TAU * (1 + 1e-5)


# ---------------------------------------------------------------------------
# model manifest + npz persistence
# ---------------------------------------------------------------------------

def test_model_save_load_roundtrip(fitted, tmp_path):
    comp, hb, archive, _ = fitted
    path = str(tmp_path / "model.npz")
    comp.save(path)
    comp2 = HierarchicalCompressor.load(path)
    assert comp2.cfg == comp.cfg
    np.testing.assert_allclose(comp2.decompress(archive),
                               comp.decompress(archive), atol=1e-6)
    # loadable with pickle hard-disabled at the numpy layer too
    np.load(path, allow_pickle=False).close()


def test_model_tamper_detected(fitted, tmp_path):
    comp, _, _, _ = fitted
    path = str(tmp_path / "model.npz")
    comp.save(path)
    data = dict(np.load(path, allow_pickle=False))
    key = next(k for k in data if k.startswith("t"))
    data[key] = data[key] + 1.0
    np.savez(path, **data)
    with pytest.raises(ChecksumMismatch):
        HierarchicalCompressor.load(path)


def test_legacy_pickle_model_rejected(fitted, tmp_path):
    path = str(tmp_path / "legacy.pkl")
    with open(path, "wb") as f:
        pickle.dump({"cfg": None}, f)
    with pytest.raises(MalformedStream):
        HierarchicalCompressor.load(path)


def test_atomic_write_failure_raises_after_retries(tmp_path):
    missing = str(tmp_path / "no" / "such" / "dir" / "f.rba")
    with pytest.raises(OSError):
        archive_io.atomic_write_bytes(missing, b"x", retries=1, backoff=0.001)
