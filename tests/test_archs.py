"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step + (where defined)
one decode step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via launch/dryrun.py (per assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import RunConfig
from repro.models.registry import get_model, reduced_config
from repro.train import optim
from repro.train.loop import init_train_state, make_train_step

RUN = RunConfig()
B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.n_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg, RUN)
    batch = _batch(cfg, key)
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    logits = api.forward(params, cfg, RUN, batch["tokens"], **extra)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = reduced_config(get_config(arch))
    opt = optim.adam(1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, RUN, opt)
    step = jax.jit(make_train_step(cfg, RUN, opt))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    # params actually changed
    flat = jax.tree.leaves(state.params)
    assert all(bool(jnp.isfinite(x).all()) for x in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduced_config(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg, RUN)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        kwargs["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model))
    state = api.init_decode_state(params, cfg, RUN, B, 64, **kwargs)
    tok = jnp.zeros((B, 1), jnp.int32)
    dec = jax.jit(lambda p, t, s: api.decode_step(p, cfg, RUN, t, s))
    logits, state = dec(params, tok, state)
    logits2, state = dec(params, tok + 1, state)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all()), arch
    assert bool(jnp.isfinite(logits2[..., :cfg.vocab]).all()), arch


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_decode_matches_forward_prefix(arch):
    """Greedy decode over a short prompt agrees with teacher-forced forward
    logits at each position (the KV cache is consistent with full attention)."""
    cfg = reduced_config(get_config(arch))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg, RUN)
    toks = jax.random.randint(jax.random.fold_in(key, 3), (1, 8), 0, cfg.vocab)
    full = api.forward(params, cfg, RUN, toks)
    state = api.init_decode_state(params, cfg, RUN, 1, 16)
    for t in range(8):
        logits, state = api.decode_step(params, cfg, RUN, toks[:, t:t + 1],
                                        state)
        np.testing.assert_allclose(np.asarray(logits[0, 0, :cfg.vocab]),
                                   np.asarray(full[0, t, :cfg.vocab]),
                                   atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m",
                                  "recurrentgemma-9b", "granite-moe-3b-a800m"])
def test_chunked_ce_matches_dense(arch):
    """The §Perf chunked LM-head+CE path is exact (not an approximation)."""
    import dataclasses
    cfg = reduced_config(get_config(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg, RUN)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    a = float(api.train_loss(params, cfg, RUN, batch))
    b = float(api.train_loss(params, cfg,
                             dataclasses.replace(RUN, ce_chunk=8), batch))
    c = float(api.train_loss(params, cfg,
                             dataclasses.replace(RUN, ce_chunk=8,
                                                 scan_layers=False), batch))
    np.testing.assert_allclose(a, b, rtol=3e-5)
    np.testing.assert_allclose(a, c, rtol=3e-5)


def test_unrolled_stack_matches_scan():
    """scan_layers=False (dry-run cost mode) computes the same function."""
    cfg = reduced_config(get_config("qwen2-1.5b"))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg, RUN)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    a = api.forward(params, cfg, RUN, toks)
    import dataclasses
    run2 = dataclasses.replace(RUN, scan_layers=False)
    b = api.forward(params, cfg, run2, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)
