"""Baseline compressors: paper-ablation block AE, sz-like, zfp-like."""
import numpy as np
import pytest

from repro.baselines import block_ae, szlike, zfplike
from repro.data import synthetic
from repro.data.blocks import Normalizer, block_nd, nrmse


@pytest.fixture(scope="module")
def field():
    return synthetic.e3sm_like(t=24, h=32, w=32, seed=0)


def test_szlike_pointwise_bound(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    for eb in (0.1, 0.01):
        dec, nbytes = szlike.compress(norm, eb)
        assert np.abs(dec - norm).max() <= eb + 1e-5
        assert nbytes < norm.size * 4


def test_szlike_monotone_tradeoff(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    curve = szlike.compression_curve(norm, [0.2, 0.02])
    assert curve[0]["cr"] > curve[1]["cr"]
    assert curve[0]["nrmse"] > curve[1]["nrmse"]


def test_zfplike_roundtrip(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    dec, nbytes = zfplike.compress(norm, 0.01)
    assert dec.shape == norm.shape
    assert np.isfinite(dec).all()
    assert nrmse(norm, dec) < 0.05
    assert nbytes < norm.size * 4


def test_zfplike_nondivisible_shapes():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((7, 9, 10)).astype(np.float32)
    dec, _ = zfplike.compress(x, 0.05)
    assert dec.shape == x.shape


def test_block_ae_baseline_trains_and_compresses(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    blocks, _ = block_nd(norm, (6, 16, 16))
    base = block_ae.BlockAEBaseline(in_dim=blocks.shape[1], hidden=64,
                                    latent=16, epochs=10, bin_size=0.02)
    base.fit(blocks, seed=0)
    recon, nbytes = base.compress(blocks)
    assert recon.shape == blocks.shape
    assert nbytes < blocks.size * 4
    assert nrmse(blocks, recon) < nrmse(blocks, np.zeros_like(blocks))
