"""Baseline compressors: paper-ablation block AE, sz-like, zfp-like."""
import numpy as np
import pytest

from repro.baselines import block_ae, szlike, zfplike
from repro.baselines.codec import Codec, Encoded, roundtrip
from repro.core.errors import ArchiveError
from repro.data import synthetic
from repro.data.blocks import Normalizer, block_nd, nrmse


@pytest.fixture(scope="module")
def field():
    return synthetic.e3sm_like(t=24, h=32, w=32, seed=0)


def test_szlike_pointwise_bound(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    for eb in (0.1, 0.01):
        dec, nbytes = szlike.compress(norm, eb)
        assert np.abs(dec - norm).max() <= eb + 1e-5
        assert nbytes < norm.size * 4


def test_szlike_monotone_tradeoff(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    curve = szlike.compression_curve(norm, [0.2, 0.02])
    assert curve[0]["cr"] > curve[1]["cr"]
    assert curve[0]["nrmse"] > curve[1]["nrmse"]


def test_zfplike_roundtrip(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    dec, nbytes = zfplike.compress(norm, 0.01)
    assert dec.shape == norm.shape
    assert np.isfinite(dec).all()
    assert nrmse(norm, dec) < 0.05
    assert nbytes < norm.size * 4


def test_zfplike_nondivisible_shapes():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((7, 9, 10)).astype(np.float32)
    dec, _ = zfplike.compress(x, 0.05)
    assert dec.shape == x.shape


def test_block_ae_baseline_trains_and_compresses(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    blocks, _ = block_nd(norm, (6, 16, 16))
    base = block_ae.BlockAEBaseline(in_dim=blocks.shape[1], hidden=64,
                                    latent=16, epochs=10, bin_size=0.02)
    base.fit(blocks, seed=0)
    recon, nbytes = base.compress(blocks)
    assert recon.shape == blocks.shape
    assert nbytes < blocks.size * 4
    assert nrmse(blocks, recon) < nrmse(blocks, np.zeros_like(blocks))


# -- unified Codec protocol ---------------------------------------------------

def test_codec_protocol_conformance():
    assert isinstance(szlike.SZLikeCodec(), Codec)
    assert isinstance(zfplike.ZFPLikeCodec(), Codec)


def test_szlike_payload_roundtrip(field):
    """The payload alone decodes, bit-identically to the encoder-side view."""
    norm = Normalizer.fit(field, "zscore").forward(field)
    c = szlike.SZLikeCodec()
    for eb in (0.1, 0.01):
        dec, enc = roundtrip(c, norm, eb)
        legacy_dec, legacy_nbytes = szlike.compress(norm, eb)
        assert np.array_equal(dec, legacy_dec)
        assert enc.nbytes == legacy_nbytes
        assert np.abs(dec - norm).max() <= eb + 1e-5


def test_zfplike_payload_roundtrip(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    c = zfplike.ZFPLikeCodec()
    dec, enc = roundtrip(c, norm, 0.01)
    legacy_dec, legacy_nbytes = zfplike.compress(norm, 0.01)
    assert np.array_equal(dec, legacy_dec)
    assert enc.nbytes == legacy_nbytes
    assert nrmse(norm, dec) < 0.05


def test_block_ae_codec_roundtrip(field):
    norm = Normalizer.fit(field, "zscore").forward(field)
    blocks, _ = block_nd(norm, (6, 16, 16))
    base = block_ae.BlockAEBaseline(in_dim=blocks.shape[1], hidden=32,
                                    latent=8, epochs=2, bin_size=0.02)
    base.fit(blocks, seed=0)
    c = base.codec()
    assert isinstance(c, Codec)
    dec, enc = roundtrip(c, blocks, base.bin_size)
    legacy_dec, legacy_nbytes = base.compress(blocks)
    assert np.array_equal(dec, legacy_dec)
    assert enc.nbytes == legacy_nbytes


def test_block_ae_codec_requires_fit():
    base = block_ae.BlockAEBaseline(in_dim=8)
    with pytest.raises(ValueError, match="fit"):
        base.codec()


@pytest.mark.parametrize("make", [szlike.SZLikeCodec, zfplike.ZFPLikeCodec])
def test_codec_rejects_malformed_payloads(make):
    c = make()
    enc = c.compress(np.zeros((16, 16), np.float32), 0.1)
    for bad in (enc.payload[:10],          # truncated header
                b"XXXX" + enc.payload[4:],  # wrong magic
                enc.payload[:-5]):          # truncated stream
        with pytest.raises(ArchiveError):
            c.decompress(Encoded(codec=c.name, payload=bad))
