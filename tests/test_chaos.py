"""Fault-tolerance tests: retry ladder determinism, deadline watchdog,
poison-stripe quarantine with the lossless verbatim fallback, chaos-harness
invariants, and the streaming ``.partial`` fuzz contract.

(Named ``test_chaos`` so it sorts before ``test_kernels`` — the kernel sweep
has a known pre-seed failure that stops ``pytest -x``.)
"""
import os
import threading
import time

import numpy as np
import pytest

import jax

from repro.core import CompressorConfig, HierarchicalCompressor
from repro.core import bae as bae_mod
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core.errors import (ArchiveError, GuaranteeUnsatisfiable,
                               MalformedStream, StageDeadlineExceeded,
                               TransientStageError)
from repro.runtime import archive_io, faultinject
from repro.runtime.chaosinject import (ChaosInjector, ChaosPermanentFault,
                                       ChaosSpec, run_chaos_check)
from repro.stream import (FaultTolerance, RetryPolicy, StageGraph, StageSpec,
                          StreamScheduler, stream_compress)


@pytest.fixture(scope="module")
def comp_hb():
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32, hb_latent=8,
                           bae_hidden=32, bae_latent=4, gae_block_elems=80,
                           hb_bin=0.01, bae_bin=0.01, gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(0))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(0)
    hb = rng.standard_normal((24, cfg.k, cfg.block_elems)).astype(np.float32)
    hb *= 0.1
    comp.fit_basis(hb)
    return comp, hb


# ---------------------------------------------------------------------------
# retry policy & scheduler-level ladder
# ---------------------------------------------------------------------------

def test_retry_policy_delay_is_deterministic_and_bounded():
    p = RetryPolicy(max_retries=5, base_backoff_s=0.01, max_backoff_s=0.1,
                    jitter=0.25, seed=3)
    d1 = [p.delay("enc", 7, a) for a in range(6)]
    d2 = [p.delay("enc", 7, a) for a in range(6)]
    assert d1 == d2                                     # pure function
    assert d1 != [p.delay("enc", 8, a) for a in range(6)]   # item-dependent
    for a, d in enumerate(d1):
        base = min(0.1, 0.01 * 2 ** a)
        assert base <= d <= base * 1.25
    assert RetryPolicy(seed=3).delay("enc", 7, 0) != \
        RetryPolicy(seed=4).delay("enc", 7, 0)          # seed-dependent


def test_scheduler_retries_transient_then_succeeds():
    calls = {}
    lock = threading.Lock()

    def flaky(i, x):
        with lock:
            calls[i] = calls.get(i, 0) + 1
        if i == 2 and calls[i] < 3:
            raise TransientStageError("flaky")
        return x * 10

    policy = RetryPolicy(max_retries=3, base_backoff_s=0.001,
                         max_backoff_s=0.005, seed=1)
    graph = StageGraph([StageSpec("flaky", flaky, workers=2, queue_depth=2,
                                  retry=policy)])
    results, stats = StreamScheduler(graph).run(list(range(5)))
    assert results == [x * 10 for x in range(5)]
    assert stats.retries == {"flaky": 2}
    assert [(e[0], e[1], e[2]) for e in stats.retry_events] == \
        [("flaky", 2, 0), ("flaky", 2, 1)]
    assert stats.retry_events[0][3] == round(policy.delay("flaky", 2, 0), 9)


def test_scheduler_retry_timeline_is_reproducible():
    def flaky(i, x):
        raise TransientStageError("always")

    def fallback(i, payload, exc):
        return -1

    timelines = []
    for _ in range(2):
        graph = StageGraph([StageSpec(
            "f", flaky, workers=3, queue_depth=2,
            retry=RetryPolicy(max_retries=2, base_backoff_s=0.001,
                              max_backoff_s=0.004, seed=9),
            fallback=fallback)])
        results, stats = StreamScheduler(graph).run(list(range(6)))
        assert results == [-1] * 6
        assert stats.failovers == {"f": 6}
        timelines.append(list(stats.retry_events))
    assert timelines[0] == timelines[1]          # canonicalized & seeded


def test_scheduler_permanent_error_skips_retries():
    attempts = {"n": 0}

    def perm(i, x):
        attempts["n"] += 1
        if i == 1:
            raise ValueError("permanent")
        return x

    graph = StageGraph([StageSpec("perm", perm, queue_depth=2,
                                  retry=RetryPolicy(max_retries=3))])
    with pytest.raises(ValueError, match="permanent"):
        StreamScheduler(graph).run([0, 1, 2])
    assert attempts["n"] == 3                    # no retry on non-transient


def test_scheduler_custom_retryable_classifier():
    calls = {"n": 0}

    def fn(i, x):
        calls["n"] += 1
        if calls["n"] == 1:
            raise OSError("disk hiccup")
        return x

    policy = RetryPolicy(max_retries=2, base_backoff_s=0.001,
                         retryable=lambda e: isinstance(e, OSError))
    graph = StageGraph([StageSpec("io", fn, retry=policy)])
    results, stats = StreamScheduler(graph).run([5])
    assert results == [5] and stats.total_retries() == 1


def test_deadline_abandons_hung_attempt_without_deadlock():
    hung = threading.Event()

    def hang_once(i, x):
        if i == 1 and not hung.is_set():
            hung.set()
            time.sleep(5.0)                      # way past the deadline
        return x + 100

    graph = StageGraph([StageSpec(
        "hang", hang_once, workers=2, queue_depth=2, deadline_s=0.05,
        retry=RetryPolicy(max_retries=2, base_backoff_s=0.001,
                          max_backoff_s=0.002))])
    t0 = time.perf_counter()
    results, stats = StreamScheduler(graph).run(list(range(4)))
    assert time.perf_counter() - t0 < 4.0        # did NOT wait out the hang
    assert results == [x + 100 for x in range(4)]
    assert stats.deadline_hits == {"hang": 1}
    assert stats.total_retries() == 1            # deadline hit is retryable


def test_deadline_exhaustion_surfaces_typed_error():
    def always_hang(i, x):
        time.sleep(5.0)

    graph = StageGraph([StageSpec("h", always_hang, deadline_s=0.02,
                                  retry=RetryPolicy(
                                      max_retries=1, base_backoff_s=0.001,
                                      max_backoff_s=0.002))])
    with pytest.raises(StageDeadlineExceeded) as ei:
        StreamScheduler(graph).run([0])
    assert ei.value.stage == "h" and ei.value.deadline_s == 0.02


def test_scheduler_shutdown_with_inflight_retries_raises_lowest_index():
    # several items exhaust their retries concurrently; the drain must
    # complete (all sentinels propagate) and the LOWEST index error wins
    def bad(i, x):
        if i in (1, 3):
            raise TransientStageError(f"bad-{i}")
        return x

    graph = StageGraph([StageSpec(
        "bad", bad, workers=3, queue_depth=2,
        retry=RetryPolicy(max_retries=2, base_backoff_s=0.001,
                          max_backoff_s=0.002))])
    with pytest.raises(TransientStageError, match="bad-1"):
        StreamScheduler(graph).run(list(range(5)))
    # the scheduler's worker threads all exited (no deadlocked queues)
    assert not [t for t in threading.enumerate()
                if t.name.startswith("stream-bad")]


def test_failing_fallback_records_error():
    def boom(i, x):
        raise ValueError("boom")

    def bad_fallback(i, payload, exc):
        raise RuntimeError("fallback also died")

    graph = StageGraph([StageSpec("b", boom, fallback=bad_fallback)])
    with pytest.raises(RuntimeError, match="fallback also died"):
        StreamScheduler(graph).run([0])


# ---------------------------------------------------------------------------
# verbatim fallback chunks
# ---------------------------------------------------------------------------

def test_verbatim_chunk_roundtrip_and_flags(comp_hb):
    comp, hb = comp_hb
    chunk = comp.encode_stripe_verbatim(7, hb[7:14])
    assert chunk.verbatim_blob and chunk.hb_stream is None
    blob = archive_io.pack_chunk_section(chunk)
    assert len(blob) == archive_io.chunk_section_size(chunk)
    back = archive_io.unpack_chunk_section(blob)
    assert back.verbatim_blob == chunk.verbatim_blob
    assert (back.hb_start, back.n_hyperblocks) == (7, 7)
    assert np.array_equal(comp.decode_stripe_verbatim(back), hb[7:14])


def test_verbatim_chunk_malformed_payload_is_typed(comp_hb):
    comp, hb = comp_hb
    chunk = comp.encode_stripe_verbatim(0, hb[:7])
    blob = archive_io.pack_chunk_section(chunk)
    with pytest.raises(ArchiveError):
        archive_io.unpack_chunk_section(blob[:-3])       # truncated
    import dataclasses as dc
    import zlib
    short = dc.replace(chunk, verbatim_blob=zlib.compress(b"\x00" * 12))
    with pytest.raises(MalformedStream, match="verbatim"):
        comp.decode_stripe_verbatim(short)               # wrong payload size


def test_quarantine_on_permanent_encode_failure(comp_hb, tmp_path,
                                                monkeypatch):
    comp, hb = comp_hb
    out = str(tmp_path / "quarantine.rba")
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    real = HierarchicalCompressor.encode_stripe_host

    def poison(self, hb_start, *args, **kwargs):
        if hb_start == 14:                       # chunk 2 is poison
            raise RuntimeError("poison stripe")
        return real(self, hb_start, *args, **kwargs)

    monkeypatch.setattr(HierarchicalCompressor, "encode_stripe_host", poison)
    ft = FaultTolerance(retry=RetryPolicy(max_retries=1,
                                          base_backoff_s=0.001,
                                          max_backoff_s=0.002))
    result = stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7,
                             out_path=out, fault_tolerance=ft)
    monkeypatch.undo()
    assert result.quarantined == [2]
    assert "poison stripe" in result.quarantine_reasons[2]
    assert result.stats.quarantined == [2]
    assert result.stats.failovers.get("host_encode") == 1
    # permanent error: the retry ladder must NOT have retried it
    assert result.stats.total_retries() == 0

    # finalized container: quarantined chunk flagged verbatim, rest
    # byte-identical to batch
    disk = archive_io.read_archive(out, strict=True)
    for i, chunk in enumerate(disk.chunks):
        if i == 2:
            assert chunk.verbatim_blob
            assert np.array_equal(comp.decode_stripe_verbatim(chunk),
                                  hb[14:21])    # lossless fallback
        else:
            assert archive_io.pack_chunk_section(chunk) == \
                archive_io.pack_chunk_section(batch.chunks[i])
    assert disk.verbatim_chunks() == [2]

    # end-to-end decode honors tau everywhere (verbatim stripe included)
    recon = comp.decompress(disk)
    errs = np.linalg.norm((hb - recon).reshape(-1, 80), axis=1)
    assert float(errs.max()) <= 0.5 * (1 + 1e-5)
    assert np.array_equal(recon[14:21], hb[14:21])


def test_quarantine_on_guarantee_unsatisfiable(comp_hb, tmp_path,
                                               monkeypatch):
    comp, hb = comp_hb
    real = HierarchicalCompressor.encode_stripe_host

    def unsatisfiable(self, hb_start, *args, **kwargs):
        if hb_start == 0:
            raise GuaranteeUnsatisfiable("bound not achievable")
        return real(self, hb_start, *args, **kwargs)

    monkeypatch.setattr(HierarchicalCompressor, "encode_stripe_host",
                        unsatisfiable)
    result = stream_compress(
        comp, hb, tau=0.5, chunk_hyperblocks=7,
        fault_tolerance=FaultTolerance(retry=RetryPolicy(
            max_retries=2, base_backoff_s=0.001, max_backoff_s=0.002)))
    monkeypatch.undo()
    assert result.quarantined == [0]
    assert result.stats.total_retries() == 0     # not transient
    recon = comp.decompress(result.archive)
    assert np.array_equal(recon[0:7], hb[0:7])


def test_no_fault_tolerance_keeps_fail_fast_semantics(comp_hb, tmp_path,
                                                      monkeypatch):
    comp, hb = comp_hb
    out = str(tmp_path / "failfast.rba")
    real = HierarchicalCompressor.encode_stripe_host

    def failing(self, hb_start, *args, **kwargs):
        if hb_start == 14:
            raise RuntimeError("hard crash")
        return real(self, hb_start, *args, **kwargs)

    monkeypatch.setattr(HierarchicalCompressor, "encode_stripe_host", failing)
    with pytest.raises(RuntimeError, match="hard crash"):
        stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7, out_path=out)
    monkeypatch.undo()
    assert not os.path.exists(out)
    assert os.path.exists(out + ".partial")


# ---------------------------------------------------------------------------
# live chaos: injector + harness invariants
# ---------------------------------------------------------------------------

def test_chaos_injector_decisions_are_seeded():
    spec = ChaosSpec(seed=5, transient_rate=0.4, permanent_rate=0.1)
    a, b = ChaosInjector(spec), ChaosInjector(spec)
    for inj in (a, b):
        for item in range(12):
            for attempt in range(3):
                try:
                    inj.before("host_encode", item, attempt)
                except (TransientStageError, ChaosPermanentFault):
                    pass
    assert a.injected == b.injected
    assert a.injected["transient"] > 0
    # permanent faults are keyed per (stage, item), NOT per attempt: a
    # poison item fails every attempt (retries can never dodge it)
    inj = ChaosInjector(spec)
    for item in range(12):
        hits = []
        for attempt in range(3):
            try:
                inj.before("host_encode", item, attempt)
                hits.append(False)
            except ChaosPermanentFault:
                hits.append(True)
            except TransientStageError:
                hits.append(False)
        assert all(hits) or not any(hits), \
            f"permanent fault flickered across attempts for item {item}"


def test_stream_compress_under_transient_chaos_is_deterministic(comp_hb,
                                                                tmp_path):
    comp, hb = comp_hb
    spec = ChaosSpec(seed=11, transient_rate=0.35)
    ft = FaultTolerance(retry=RetryPolicy(max_retries=4,
                                          base_backoff_s=0.002,
                                          max_backoff_s=0.01, seed=11))
    runs = []
    for r in range(2):
        out = str(tmp_path / f"chaos{r}.rba")
        result = stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7,
                                 out_path=out, fault_tolerance=ft,
                                 chaos=ChaosInjector(spec))
        runs.append((tuple(result.stats.retry_events),
                     tuple(result.quarantined)))
        # transient-only chaos: retries absorb everything, no quarantine,
        # container byte-identical to batch
        assert result.quarantined == []
    assert runs[0] == runs[1]
    assert runs[0][0]                            # chaos actually injected
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    with open(str(tmp_path / "chaos0.rba"), "rb") as f:
        assert f.read() == archive_io.serialize_archive(batch)


def test_run_chaos_check_invariant_harness(comp_hb, tmp_path):
    comp, hb = comp_hb
    report = run_chaos_check(
        comp, hb, 0.5,
        ChaosSpec(seed=3, transient_rate=0.25, permanent_rate=0.2),
        str(tmp_path / "harness.rba"), scenario="test", budget_s=60.0)
    assert report.ok, report.summary()
    assert report.quarantined > 0                # permanent faults landed
    assert "OK" in report.summary()


# ---------------------------------------------------------------------------
# codec pool resilience & partial fuzz
# ---------------------------------------------------------------------------

def test_pool_submit_recovers_from_reset():
    exec_mod.reset_pool()
    assert exec_mod.pool_submit(lambda x: x + 1, 41).result() == 42
    exec_mod.reset_pool()                        # kill it again mid-flight
    assert exec_mod.pool_submit(lambda x: x * 2, 21).result() == 42


def test_partial_fuzz_containment(comp_hb, tmp_path, monkeypatch):
    comp, hb = comp_hb
    out = str(tmp_path / "fuzzme.rba")
    real = HierarchicalCompressor.encode_stripe_host

    def failing(self, hb_start, *args, **kwargs):
        if hb_start == 14:
            raise RuntimeError("crash")
        return real(self, hb_start, *args, **kwargs)

    monkeypatch.setattr(HierarchicalCompressor, "encode_stripe_host", failing)
    with pytest.raises(RuntimeError):
        stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7, out_path=out)
    monkeypatch.undo()
    with open(out + ".partial", "rb") as f:
        partial = f.read()
    result = faultinject.check_partial_containment(
        partial, trials=24, seed=1,
        decode=lambda a: comp.decompress(a, strict=False))
    assert result.ok, result.summary()
    # trial 0 fuzzes nothing: the as-left partial must salvage cleanly
    assert result.trials[0].kind == "as_left_on_disk"
    assert result.trials[0].outcome == "survived"
