"""Checkpoint/restart, torn-save fallback, retention, elastic resharding, and
error-bounded compressed checkpoints."""
from __future__ import annotations

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import (CheckpointManager, restore_compressed,
                                      save_compressed)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (32, 16)),
                      "b": jnp.zeros((16,))},
            "step": jnp.asarray(seed, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree(3)
    mgr.save(3, t)
    step, back = mgr.restore()
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t, back)


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert mgr.latest_step() == 1


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), retention=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_torn_save_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    # corrupt the newest step (simulated torn write)
    with open(os.path.join(mgr._step_dir(2), "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    step, back = mgr.restore()
    assert step == 1
    assert int(back["step"]) == 1


def test_hash_mismatch_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, _tree(5))
    # tamper one tensor but keep the npz valid
    d = mgr._step_dir(5)
    data = dict(np.load(os.path.join(d, "arrays.npz")))
    data["t0"] = data["t0"] + 1.0
    np.savez(os.path.join(d, "arrays.npz"), **data)
    with pytest.raises(FileNotFoundError):
        mgr.restore()


def test_elastic_restore_onto_different_device_count(tmp_path):
    """Save from a 1-device layout, restore sharded onto N host devices (or
    1 — the point is the API path: logical arrays -> any mesh)."""
    from jax.sharding import PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree(7)
    mgr.save(7, t)
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    specs = {"layer": {"w": P(), "b": P()}, "step": P()}
    step, back = mgr.restore(mesh=mesh, shardings=specs)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t, back)


def test_compressed_checkpoint_error_bound(tmp_path):
    """Every float block of the restored tree obeys ||x - x^G||_2 <= tau."""
    path = str(tmp_path / "ck.gae")
    # trained-net-like weights: low-rank structure + small noise (pure iid
    # noise is incompressible and falls back to raw storage — also tested)
    k = jax.random.PRNGKey(0)
    lowrank = (jax.random.normal(k, (2000, 4)) @
               jax.random.normal(jax.random.fold_in(k, 1), (4, 64)))
    tree = {"big": lowrank + 0.01 * jax.random.normal(
                jax.random.fold_in(k, 2), (2000, 64)),
            "small": jnp.arange(5, dtype=jnp.float32),
            "ints": jnp.arange(10, dtype=jnp.int32)}
    tau = 0.5
    stats = save_compressed(path, tree, tau=tau, bin_size=1e-3, block=64,
                            min_size=1024)
    back = restore_compressed(path)
    assert stats["ratio"] > 1.0
    np.testing.assert_array_equal(np.asarray(back["ints"]),
                                  np.asarray(tree["ints"]))
    np.testing.assert_array_equal(np.asarray(back["small"]),
                                  np.asarray(tree["small"]))
    flat = np.asarray(tree["big"], np.float32).reshape(-1)
    rflat = np.asarray(back["big"], np.float32).reshape(-1)
    pad = -flat.size % 64
    fb = np.pad(flat, (0, pad)).reshape(-1, 64)
    rb = np.pad(rflat, (0, pad)).reshape(-1, 64)
    errs = np.linalg.norm(fb - rb, axis=1)
    assert errs.max() <= tau * (1 + 1e-5)


def test_resilient_runner_recovers_from_injected_failures(tmp_path):
    """Crash at steps 3 and 7 -> runner restores and completes all steps with
    the deterministic data stream intact."""
    from repro.runtime.failures import ResilientRunner, chaos_wrap

    seen_batches = []

    def step_fn(state, batch):
        seen_batches.append(int(batch["i"]))
        return state + 1, {"loss": 1.0 / (state + 1.0)}

    def data_iter(start):
        def gen():
            s = start
            while True:
                yield {"i": s}
                s += 1
        return iter(gen())

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(chaos_wrap(step_fn, {3, 7}), mgr, data_iter,
                             save_every=2, max_retries=5)
    state, end = runner.run(jnp.zeros(()), 0, 10)
    assert end == 10
    assert runner.stats.restores == 2
    # deterministic replay: the exact restored-step batches were re-seen
    assert sorted(set(seen_batches)) == list(range(10))


def test_runner_skips_nan_batches(tmp_path):
    def step_fn(state, batch):
        loss = float("nan") if int(batch["i"]) == 2 else 0.5
        return state + 1, {"loss": jnp.asarray(loss)}

    def data_iter(start):
        def gen():
            s = start
            while True:
                yield {"i": s}
                s += 1
        return iter(gen())

    from repro.runtime.failures import ResilientRunner
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(step_fn, mgr, data_iter, save_every=100,
                             anomaly_policy="skip")
    _, end = runner.run(jnp.zeros(()), 0, 6)
    assert runner.stats.skipped_batches == 1
    assert end == 6
