"""Streaming subsystem tests: scheduler semantics (backpressure, ordering,
deterministic errors), stream/batch byte-identity, the two-phase streaming
archive writer, and crash-mid-stream salvage via tolerant reads.

(Named ``test_compress_stream`` so it sorts before ``test_kernels`` — the
kernel sweep has a known pre-seed failure that stops ``pytest -x``.)
"""
import os
import threading
import time

import numpy as np
import pytest

import jax

from repro.core import CompressorConfig, HierarchicalCompressor
from repro.core import bae as bae_mod
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core.errors import ChecksumMismatch
from repro.runtime import archive_io, faultinject
from repro.runtime.stream_writer import StreamingArchiveWriter, \
    WriterStateError
from repro.stream import StageGraph, StageSpec, StreamScheduler, \
    stream_compress


@pytest.fixture(scope="module")
def comp_hb():
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32, hb_latent=8,
                           bae_hidden=32, bae_latent=4, gae_block_elems=80,
                           hb_bin=0.01, bae_bin=0.01, gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(0))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(0)
    hb = rng.standard_normal((24, cfg.k, cfg.block_elems)).astype(np.float32)
    hb *= 0.1
    comp.fit_basis(hb)
    return comp, hb


# ---------------------------------------------------------------------------
# scheduler semantics
# ---------------------------------------------------------------------------

def test_scheduler_orders_results_despite_unordered_completion():
    # stage with several workers and index-dependent latency: completion
    # order scrambles, result order must not
    def jitter(i, x):
        time.sleep(0.002 * ((x * 7) % 5))
        return x * x
    graph = StageGraph([StageSpec("jitter", jitter, workers=4,
                                  queue_depth=4)])
    results, stats = StreamScheduler(graph).run(list(range(20)))
    assert results == [x * x for x in range(20)]
    assert stats.n_items == 20 and stats.wall_s > 0


def test_scheduler_backpressure_bounds_queues():
    def fast(i, x):
        return x + 1

    def slow(i, x):
        time.sleep(0.003)
        return x * 10
    graph = StageGraph([StageSpec("fast", fast, queue_depth=1),
                        StageSpec("slow", slow, queue_depth=2)])
    results, stats = StreamScheduler(graph).run(list(range(16)))
    assert results == [(x + 1) * 10 for x in range(16)]
    # the bounded queue in front of the slow stage can never exceed its depth
    assert stats.queue_high_water["slow"] <= 2
    assert stats.queue_high_water["fast"] <= 1


def test_scheduler_raises_lowest_index_error_deterministically():
    for _ in range(5):
        seen = []
        lock = threading.Lock()

        def fn(i, x):
            with lock:
                seen.append(x)
            if x in (2, 5):
                time.sleep(0.001 * (5 - x))   # let index 5 fail FIRST
                raise ValueError(f"boom-{x}")
            return x
        graph = StageGraph([StageSpec("fn", fn, workers=3, queue_depth=4)])
        with pytest.raises(ValueError, match="boom-2"):
            StreamScheduler(graph).run(list(range(8)))
        assert sorted(seen) == list(range(8))   # no short-circuit: all ran


def test_scheduler_multistage_error_drops_item_but_drains():
    done = []

    def explode(i, x):
        if x == 1:
            raise RuntimeError("stage1 fail")
        return x

    def collect(i, x):
        done.append(x)
        return x
    graph = StageGraph([StageSpec("explode", explode, queue_depth=2),
                        StageSpec("collect", collect, queue_depth=2)])
    with pytest.raises(RuntimeError, match="stage1 fail"):
        StreamScheduler(graph).run([0, 1, 2, 3])
    assert sorted(done) == [0, 2, 3]   # item 1 dropped, everything drained


def test_scheduler_validates_graph():
    with pytest.raises(ValueError):
        StageGraph([])
    with pytest.raises(ValueError):
        StageGraph([StageSpec("a", lambda i, x: x),
                    StageSpec("a", lambda i, x: x)])
    with pytest.raises(ValueError):
        StageSpec("w", lambda i, x: x, workers=0)


# ---------------------------------------------------------------------------
# stream/batch byte-identity
# ---------------------------------------------------------------------------

def test_stream_matches_batch_byte_for_byte(comp_hb, tmp_path):
    comp, hb = comp_hb
    out = str(tmp_path / "stream.rba")
    exec_mod.reset_stage_stats()
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    result = stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7,
                             out_path=out)
    blob = archive_io.serialize_archive(batch)
    assert archive_io.serialize_archive(result.archive) == blob
    with open(out, "rb") as f:
        assert f.read() == blob
    assert result.bytes_written == len(blob)
    assert result.archive.compressed_bytes() == batch.compressed_bytes()
    assert not os.path.exists(out + ".partial")   # finalize cleaned up
    # guarantee survives the streamed container round-trip
    recon = comp.decompress(archive_io.read_archive(out))
    errs = np.linalg.norm((hb - recon).reshape(-1, 80), axis=1)
    assert float(errs.max()) <= 0.5 * (1 + 1e-5)
    # pipeline behavior was measured and surfaced through exec counters
    counters = exec_mod.counters()
    assert counters["stream.overlap_s"] >= 0
    assert "stream.queue_high_water.host_encode" in counters
    assert any(k.startswith("stream.") for k in exec_mod.stage_stats())


def test_stream_without_gae_or_output(comp_hb):
    comp, hb = comp_hb
    batch = comp.compress(hb, tau=None, chunk_hyperblocks=5)
    result = stream_compress(comp, hb, tau=None, chunk_hyperblocks=5)
    assert result.bytes_written == 0
    assert archive_io.serialize_archive(result.archive) == \
        archive_io.serialize_archive(batch)


# ---------------------------------------------------------------------------
# streaming archive writer
# ---------------------------------------------------------------------------

def test_writer_out_of_order_appends_finalize_identical(comp_hb, tmp_path):
    comp, hb = comp_hb
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    spans = [(c.hb_start, c.n_hyperblocks) for c in batch.chunks]
    out = str(tmp_path / "ooo.rba")
    w = StreamingArchiveWriter(out, n_hyperblocks=batch.n_hyperblocks,
                               n_values=batch.n_values,
                               chunk_hyperblocks=batch.chunk_hyperblocks,
                               gae_dim=batch.gae_dim, spans=spans)
    for i in (2, 0, 3, 1):                    # scrambled arrival
        w.append(i, batch.chunks[i])
    assert w.appended() == 4
    nbytes = w.finalize()
    blob = archive_io.serialize_archive(batch)
    with open(out, "rb") as f:
        assert f.read() == blob
    assert nbytes == len(blob)


def test_writer_protocol_errors(comp_hb, tmp_path):
    comp, hb = comp_hb
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    spans = [(c.hb_start, c.n_hyperblocks) for c in batch.chunks]
    out = str(tmp_path / "proto.rba")
    w = StreamingArchiveWriter(out, n_hyperblocks=batch.n_hyperblocks,
                               n_values=batch.n_values,
                               chunk_hyperblocks=batch.chunk_hyperblocks,
                               gae_dim=batch.gae_dim, spans=spans)
    w.append(0, batch.chunks[0])
    # byte-identical re-append is a no-op (idempotent under sink retry) ...
    w.append(0, batch.chunks[0])
    assert w.appended() == 1
    # ... but different bytes for an already-seen slot is still a protocol
    # error (same span, verbatim re-encoding => different section bytes)
    tampered = comp.encode_stripe_verbatim(
        batch.chunks[0].hb_start, hb[:batch.chunks[0].n_hyperblocks])
    assert archive_io.pack_chunk_section(tampered) != \
        archive_io.pack_chunk_section(batch.chunks[0])
    with pytest.raises(WriterStateError, match="different bytes"):
        w.append(0, tampered)
    with pytest.raises(WriterStateError, match="span table"):
        w.append(1, batch.chunks[2])          # wrong hb range for slot 1
    with pytest.raises(WriterStateError, match="outside"):
        w.append(99, batch.chunks[0])
    with pytest.raises(WriterStateError, match="finalize"):
        w.finalize()                          # chunks missing
    w.abort()
    with pytest.raises(WriterStateError, match="aborted"):
        w.append(1, batch.chunks[1])
    assert os.path.exists(out + ".partial")   # abort preserves the partial


def test_partial_is_salvageable_after_every_append(comp_hb, tmp_path):
    comp, hb = comp_hb
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    spans = [(c.hb_start, c.n_hyperblocks) for c in batch.chunks]
    out = str(tmp_path / "salvage.rba")
    w = StreamingArchiveWriter(out, n_hyperblocks=batch.n_hyperblocks,
                               n_values=batch.n_values,
                               chunk_hyperblocks=batch.chunk_hyperblocks,
                               gae_dim=batch.gae_dim, spans=spans)
    for appended in range(len(spans)):
        with open(out + ".partial", "rb") as f:
            data = f.read()
        # strict read must refuse a partial (placeholder digests can't pass)
        with pytest.raises(ChecksumMismatch):
            archive_io.deserialize_archive(data, strict=True)
        salvaged = archive_io.deserialize_archive(data, strict=False)
        good = [i for i, c in enumerate(salvaged.chunks) if c is not None]
        assert good == list(range(appended))
        assert set(salvaged.chunk_errors) == \
            set(range(appended, len(spans)))
        w.append(appended, batch.chunks[appended])
    w.finalize()
    assert archive_io.read_archive(out, strict=True) is not None


# ---------------------------------------------------------------------------
# crash mid-stream: truncation on a partially finalized streaming archive
# ---------------------------------------------------------------------------

def test_crash_mid_stream_truncation_salvage(comp_hb, tmp_path):
    comp, hb = comp_hb
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    spans = [(c.hb_start, c.n_hyperblocks) for c in batch.chunks]
    assert spans == [(0, 7), (7, 7), (14, 7), (21, 3)]
    out = str(tmp_path / "crash.rba")
    w = StreamingArchiveWriter(out, n_hyperblocks=batch.n_hyperblocks,
                               n_values=batch.n_values,
                               chunk_hyperblocks=batch.chunk_hyperblocks,
                               gae_dim=batch.gae_dim, spans=spans)
    for i in range(3):                        # chunk 3 never lands
        w.append(i, batch.chunks[i])
    w.abort()
    with open(out + ".partial", "rb") as f:
        partial = f.read()

    # torn write: cut INSIDE chunk 2's section, so the disk holds the header,
    # meta, chunks 0-1 whole and chunk 2 half-written
    names = [archive_io._META_NAME] + [archive_io.chunk_section_name(i)
                                       for i in range(len(spans))]
    head = archive_io.head_size(names)
    meta = archive_io.build_meta_blob(
        n_hyperblocks=batch.n_hyperblocks, n_values=batch.n_values,
        chunk_hyperblocks=batch.chunk_hyperblocks, gae_dim=batch.gae_dim,
        spans=spans)
    cut = (head + len(meta)
           + archive_io.chunk_section_size(batch.chunks[0])
           + archive_io.chunk_section_size(batch.chunks[1])
           + archive_io.chunk_section_size(batch.chunks[2]) // 2)
    torn = partial[:cut]

    salvaged = archive_io.deserialize_archive(torn, strict=False)
    assert [c is not None for c in salvaged.chunks] == \
        [True, True, False, False]
    recon, report = comp.decompress(salvaged, strict=False)
    assert not report.ok
    assert [(d.chunk, d.hb_start, d.n_hyperblocks) for d in report.damaged] \
        == [(2, 14, 7), (3, 21, 3)]           # accurate damage accounting
    # every completed chunk still satisfies the per-block guarantee
    good = recon[:14]
    errs = np.linalg.norm((hb[:14] - good).reshape(-1, 80), axis=1)
    assert float(errs.max()) <= 0.5 * (1 + 1e-5)

    # and random truncations of the partial stay inside the typed-error
    # contract (detected or survived, never an escaped raw exception)
    rng = np.random.default_rng(7)
    for _ in range(24):
        bad = faultinject.corrupt(partial, "truncate", rng)
        try:
            arch = archive_io.deserialize_archive(bad, strict=False)
            comp.decompress(arch, strict=False)
        except archive_io.ArchiveError:
            pass


def test_stream_compress_failure_keeps_salvageable_partial(comp_hb, tmp_path,
                                                           monkeypatch):
    comp, hb = comp_hb
    out = str(tmp_path / "fail.rba")
    real = HierarchicalCompressor.encode_stripe_host

    def failing(self, hb_start, *args, **kwargs):
        if hb_start == 14:                    # chunk 2 of 4 dies
            raise RuntimeError("simulated encoder crash")
        return real(self, hb_start, *args, **kwargs)
    monkeypatch.setattr(HierarchicalCompressor, "encode_stripe_host", failing)
    with pytest.raises(RuntimeError, match="simulated encoder crash"):
        stream_compress(comp, hb, tau=0.5, chunk_hyperblocks=7, out_path=out)
    monkeypatch.undo()
    assert not os.path.exists(out)            # never finalized
    with open(out + ".partial", "rb") as f:
        partial = f.read()
    salvaged = archive_io.deserialize_archive(partial, strict=False)
    good = [i for i, c in enumerate(salvaged.chunks) if c is not None]
    assert good == [0, 1]                     # chunks before the crash landed
    batch = comp.compress(hb, tau=0.5, chunk_hyperblocks=7)
    for i in good:                            # and are byte-exact vs batch
        assert archive_io.pack_chunk_section(salvaged.chunks[i]) == \
            archive_io.pack_chunk_section(batch.chunks[i])
