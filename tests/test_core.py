"""Unit tests for the paper's core: HBAE, BAE, GAE (Algorithm 1 equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bae as bae_mod
from repro.core import gae
from repro.core import hbae as hbae_mod
from repro.core.attention import attention_block, attention_block_init


def test_attention_block_shapes_and_residual():
    key = jax.random.PRNGKey(0)
    params = attention_block_init(key, d=32, heads=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 7, 32))
    y = attention_block(params, x)
    assert y.shape == x.shape
    # with zeroed value/out projections the block must reduce to identity
    params2 = jax.tree.map(lambda a: jnp.zeros_like(a) if hasattr(a, "shape") else a,
                           params)
    y2 = attention_block(params2, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(x), atol=1e-6)


def test_attention_multihead_matches_singlehead_dims():
    key = jax.random.PRNGKey(0)
    params = attention_block_init(key, d=64, heads=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 64))
    assert attention_block(params, x).shape == (3, 10, 64)


def test_hbae_roundtrip_shapes():
    key = jax.random.PRNGKey(0)
    p = hbae_mod.hbae_init(key, in_dim=80, k=10, emb=32, hidden=64, latent=24)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 10, 80))
    y, lat = hbae_mod.hbae_apply(p, x)
    assert y.shape == (6, 10, 80)
    assert lat.shape == (6, 24)


def test_hbae_no_attention_variant():
    key = jax.random.PRNGKey(0)
    p = hbae_mod.hbae_init(key, in_dim=16, k=4, emb=8, hidden=16, latent=8,
                           use_attention=False)
    assert "enc_attn" not in p
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 16))
    y, _ = hbae_mod.hbae_apply(p, x)
    assert y.shape == x.shape


def test_hbae_trains_under_jit():
    from repro.core import training
    rng = np.random.default_rng(0)
    # rank-4 data (4 < latent 8): compressible, so the AE must beat the mean
    lat = rng.standard_normal((32, 1, 4)).astype(np.float32)
    mix = rng.standard_normal((4, 20)).astype(np.float32)
    data = np.tile(lat @ mix, (1, 4, 1)) + 0.01 * rng.standard_normal((32, 4, 20)).astype(np.float32)
    p = training.train_hbae(jax.random.PRNGKey(0), data, emb=16, hidden=32,
                            latent=8, epochs=120, batch=16)
    y, _ = hbae_mod.hbae_apply(p, jnp.asarray(data))
    mse = float(jnp.mean(jnp.square(y - data)))
    assert mse < float(np.var(data)) * 0.5, mse  # beats predicting the mean


def test_bae_roundtrip_shapes():
    p = bae_mod.bae_init(jax.random.PRNGKey(0), in_dim=80, hidden=64, latent=16)
    r = jax.random.normal(jax.random.PRNGKey(1), (12, 80)) * 0.01
    r_hat, lb = bae_mod.bae_apply(p, r)
    assert r_hat.shape == (12, 80) and lb.shape == (12, 16)


# ---------------------------------------------------------------------------
# GAE
# ---------------------------------------------------------------------------

def _setup_gae(n=40, d=24, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    x_r = x + 0.3 * rng.standard_normal((n, d)).astype(np.float32)
    basis = np.asarray(gae.fit_pca_basis(jnp.asarray(x - x_r)))
    return x, x_r, basis


def test_pca_basis_orthonormal():
    _, _, basis = _setup_gae()
    np.testing.assert_allclose(basis.T @ basis, np.eye(basis.shape[0]),
                               atol=1e-4)


def test_gae_select_matches_reference_loop():
    x, x_r, basis = _setup_gae()
    tau, bin_size = 0.8, 0.01
    sel = gae.gae_select(jnp.asarray(x - x_r), jnp.asarray(basis), tau, bin_size)
    ref_out, ref_ms = gae.gae_reference_loop(x, x_r, basis, tau, bin_size)
    np.testing.assert_array_equal(np.asarray(sel.m), np.asarray(ref_ms))
    np.testing.assert_allclose(x_r + np.asarray(sel.corrected), ref_out,
                               atol=1e-4)


def test_gae_select_zero_m_for_small_residuals():
    x, x_r, basis = _setup_gae()
    sel = gae.gae_select(jnp.asarray(x - x_r), jnp.asarray(basis), tau=1e9,
                         bin_size=0.01)
    assert int(np.asarray(sel.m).max()) == 0


def test_gae_encode_blocks_hard_bound_and_roundtrip():
    x, x_r, basis = _setup_gae()
    tau, bin_size = 0.5, 0.02
    out, codes = gae.gae_encode_blocks(x, x_r, basis, tau, bin_size)
    errs = np.linalg.norm(x - out, axis=1)
    assert np.all(errs <= tau + 1e-5), errs.max()
    dec = gae.gae_decode_blocks(x_r, basis, codes, bin_size)
    np.testing.assert_allclose(dec, out, atol=1e-5)


def test_gae_encode_blocks_coarse_bin_fallback():
    # bin so coarse the global size can never satisfy tau without refinement
    x, x_r, basis = _setup_gae()
    tau = 0.05
    out, codes = gae.gae_encode_blocks(x, x_r, basis, tau, bin_size=10.0)
    errs = np.linalg.norm(x - out, axis=1)
    assert np.all(errs <= tau + 1e-5)
    assert any(c.bin_exp > 0 for c in codes)
