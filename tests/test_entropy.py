"""Huffman / index-set / quantization bitstream tests (incl. hypothesis)."""
import numpy as np
import pytest
from hypothesis_fallback import given, settings, st

from repro.core import entropy
from repro.core.errors import MalformedStream, TruncatedArchive
from repro.core.quantization import dequantize, quantize, quantization_error_bound
import jax.numpy as jnp


def test_huffman_roundtrip_basic():
    rng = np.random.default_rng(0)
    vals = rng.integers(-50, 50, size=5000).astype(np.int64)
    stream = entropy.huffman_compress(vals)
    out = entropy.huffman_decompress(stream)
    np.testing.assert_array_equal(out, vals)


def test_huffman_skewed_distribution_compresses():
    rng = np.random.default_rng(1)
    vals = np.round(rng.standard_normal(20000) * 3).astype(np.int64)
    stream = entropy.huffman_compress(vals)
    assert stream.nbytes() < vals.size * 8 * 0.25  # well under raw int64


def test_huffman_single_symbol():
    vals = np.zeros(100, np.int64)
    stream = entropy.huffman_compress(vals)
    np.testing.assert_array_equal(entropy.huffman_decompress(stream), vals)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=500))
def test_huffman_roundtrip_property(values):
    vals = np.asarray(values, np.int64)
    stream = entropy.huffman_compress(vals)
    np.testing.assert_array_equal(entropy.huffman_decompress(stream), vals)


def test_index_sets_roundtrip():
    rng = np.random.default_rng(2)
    dim = 96
    sets = [np.sort(rng.choice(dim, size=rng.integers(0, 20), replace=False)
                    ).astype(np.int64) for _ in range(50)]
    blob = entropy.encode_index_sets([s.astype(np.int32) for s in sets], dim)
    out = entropy.decode_index_sets(blob)
    assert len(out) == len(sets)
    for a, b in zip(sets, out):
        np.testing.assert_array_equal(a, b)


def test_index_sets_empty():
    blob = entropy.encode_index_sets([np.zeros(0, np.int32)] * 3, 16)
    out = entropy.decode_index_sets(blob)
    assert len(out) == 3 and all(s.size == 0 for s in out)


@settings(max_examples=25, deadline=None)
@given(st.floats(1e-4, 10.0), st.lists(st.floats(-100, 100, allow_nan=False,
                                                 width=32), min_size=1, max_size=64))
def test_quantization_error_within_half_bin(bin_size, values):
    x = jnp.asarray(np.asarray(values, np.float32))
    deq = dequantize(quantize(x, bin_size), bin_size)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert np.all(err <= bin_size / 2 + 1e-5 * bin_size + 1e-6)


def test_huffman_truncated_payload_raises_typed():
    vals = np.arange(-100, 100, dtype=np.int64).repeat(20)
    stream = entropy.huffman_compress(vals)
    cut = entropy.HuffmanStream(stream.payload[:len(stream.payload) // 4],
                                stream.book, stream.count)
    with pytest.raises((TruncatedArchive, MalformedStream)):
        entropy.huffman_decompress(cut)


def test_huffman_rebuild_book_rejects_bad_lengths():
    with pytest.raises(MalformedStream):
        entropy.rebuild_book(np.array([1, 2], np.int64),
                             np.array([0, 3], np.uint8))      # length 0
    with pytest.raises(MalformedStream):
        entropy.rebuild_book(np.array([1, 2], np.int64),
                             np.array([17, 17], np.uint8))    # > MAX_CODE_LEN
    with pytest.raises(MalformedStream):
        entropy.rebuild_book(np.array([1, 2], np.int64),
                             np.array([3, 2], np.uint8))      # not canonical
    with pytest.raises(MalformedStream):
        entropy.rebuild_book(np.array([1, 2, 3], np.int64),
                             np.array([1, 1, 1], np.uint8))   # Kraft violation
    with pytest.raises(MalformedStream):
        entropy.rebuild_book(np.array([1], np.int64),
                             np.array([1, 1], np.uint8))      # size mismatch


def test_huffman_rebuild_book_roundtrip():
    vals = np.round(np.random.default_rng(3).standard_normal(4000) * 5
                    ).astype(np.int64)
    stream = entropy.huffman_compress(vals)
    book2 = entropy.rebuild_book(stream.book.symbols, stream.book.lengths)
    np.testing.assert_array_equal(book2.codes, stream.book.codes)
    np.testing.assert_array_equal(
        entropy.huffman_decode(stream.payload, book2, stream.count), vals)


def test_index_sets_garbage_raises_typed():
    with pytest.raises(MalformedStream):
        entropy.decode_index_sets(b"definitely not deflate")
    # valid deflate, garbage header inside
    import zlib as _z
    with pytest.raises((MalformedStream, TruncatedArchive)):
        entropy.decode_index_sets(_z.compress(b"\x01"))


def test_index_sets_cross_checks():
    sets = [np.array([0, 3], np.int32), np.array([1], np.int32)]
    blob = entropy.encode_index_sets(sets, 8)
    with pytest.raises(MalformedStream):
        entropy.decode_index_sets(blob, expect_dim=16)
    with pytest.raises(MalformedStream):
        entropy.decode_index_sets(blob, expect_sets=3)
    out = entropy.decode_index_sets(blob, expect_dim=8, expect_sets=2)
    np.testing.assert_array_equal(out[0], sets[0])


def test_zlib_unpack_garbage_raises_typed():
    with pytest.raises(MalformedStream):
        entropy.zlib_unpack(b"\x00\x01\x02")


def test_quantization_l2_bound_formula():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    b = 0.05
    deq = dequantize(quantize(x, b), b)
    l2 = float(np.linalg.norm(np.asarray(deq - x)))
    assert l2 <= quantization_error_bound(b, 256) + 1e-6
