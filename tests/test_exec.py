"""Hot-path execution layer tests: persistent jit cache + retrace accounting,
fused stage programs, chunk-parallel codecs, and the guarantee/accounting
bugfix regressions (GuaranteeUnsatisfiable, model_bytes dtypes, cached
compressed_bytes, strict/tolerant decode parity)."""
import numpy as np
import pytest

import jax

from repro.core import CompressorConfig, HierarchicalCompressor
from repro.core import bae as bae_mod
from repro.core import entropy, gae
from repro.core import exec as exec_mod
from repro.core import hbae as hbae_mod
from repro.core.errors import GuaranteeUnsatisfiable, MalformedStream
from repro.runtime import archive_io


# ---------------------------------------------------------------------------
# fixtures: an UNTRAINED compressor (random init) — the hot path, codecs and
# guarantees don't care whether the AE is good, and skipping fit() keeps the
# suite fast.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def comp_hb():
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32, hb_latent=8,
                           bae_hidden=32, bae_latent=4, gae_block_elems=80,
                           hb_bin=0.01, bae_bin=0.01, gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    key = jax.random.PRNGKey(0)
    khb, kb = jax.random.split(key)
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(0)
    hb = rng.standard_normal((24, cfg.k, cfg.block_elems)).astype(np.float32)
    hb *= 0.1
    comp.fit_basis(hb)
    return comp, hb


# ---------------------------------------------------------------------------
# persistent jit cache
# ---------------------------------------------------------------------------

def test_jit_cache_returns_same_wrapper():
    c = exec_mod.JitCache()
    f = lambda x: x + 1
    w1 = c.get("inc", f)
    w2 = c.get("inc", f)
    assert w1 is w2
    # different statics => distinct compiled wrapper
    w3 = c.get("inc", f, static_argnums=(0,))
    assert w3 is not w1


def test_jit_cache_counts_retraces_not_calls():
    c = exec_mod.JitCache()
    sq = c.get("sq", lambda x: x * x)
    x4 = np.arange(4, dtype=np.float32)
    sq(x4)
    sq(x4 + 1)                      # same shape/dtype: cache hit
    sq(x4 + 2)
    assert c.retrace_counts() == {"sq": 1}
    sq(np.arange(5, dtype=np.float32))   # new shape: one more trace
    assert c.retrace_counts() == {"sq": 2}
    assert c.total_retraces() == 2


def test_roundtrip_retrace_stable_after_warmup(comp_hb):
    comp, hb = comp_hb
    # warmup traces every program for this shape
    a = comp.compress(hb, tau=0.5)
    comp.decompress(a)
    before = exec_mod.total_retraces()
    for _ in range(2):
        a = comp.compress(hb, tau=0.5)
        comp.decompress(a)
    assert exec_mod.total_retraces() == before, exec_mod.retrace_counts()


def test_stage_stats_accumulate():
    exec_mod.reset_stage_stats()
    with exec_mod.stage("unit_test_stage", 100):
        pass
    with exec_mod.stage("unit_test_stage", 50):
        pass
    st = exec_mod.stage_stats()["unit_test_stage"]
    assert st.calls == 2 and st.values == 150 and st.seconds >= 0.0
    assert "unit_test_stage" in exec_mod.stats_summary()
    exec_mod.reset_stage_stats()
    assert "unit_test_stage" not in exec_mod.stage_stats()


def test_map_parallel_preserves_order(monkeypatch):
    items = list(range(37))
    assert exec_mod.map_parallel(lambda x: x * x, items) == \
        [x * x for x in items]
    # forced-serial configuration must agree bit-for-bit
    monkeypatch.setenv("REPRO_CODEC_WORKERS", "1")
    assert exec_mod.map_parallel(lambda x: x * x, items) == \
        [x * x for x in items]


def test_map_parallel_raises_lowest_index_error(monkeypatch):
    # several items fail, the later one FINISHES first — the propagated
    # exception must still be the lowest failing index's, exactly what the
    # serial loop would raise
    monkeypatch.setenv("REPRO_CODEC_WORKERS", "4")

    def fn(x):
        if x in (3, 9):
            import time
            time.sleep(0.002 if x == 3 else 0.0)
            raise ValueError(f"item-{x}")
        return x
    for _ in range(5):
        with pytest.raises(ValueError, match="item-3"):
            exec_mod.map_parallel(fn, range(12))
    # serial path agrees
    monkeypatch.setenv("REPRO_CODEC_WORKERS", "1")
    with pytest.raises(ValueError, match="item-3"):
        exec_mod.map_parallel(fn, range(12))


def test_stage_and_counter_accumulation_thread_safe():
    import threading
    exec_mod.reset_stage_stats()
    n_threads, n_iter = 8, 200

    def hammer():
        for _ in range(n_iter):
            exec_mod.record_stage("mt_stage", 0.001, n_values=10)
            exec_mod.counter_add("mt_counter", 1.0)
            exec_mod.counter_max("mt_gauge", 7.0)
    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = exec_mod.stage_stats()["mt_stage"]
    # no lost updates: every read-modify-write landed
    assert st.calls == n_threads * n_iter
    assert st.values == n_threads * n_iter * 10
    assert st.seconds == pytest.approx(n_threads * n_iter * 0.001)
    counters = exec_mod.counters()
    assert counters["mt_counter"] == n_threads * n_iter
    assert counters["mt_gauge"] == 7.0
    assert "mt_counter: 1600" in exec_mod.stats_summary()
    exec_mod.reset_stage_stats()
    assert exec_mod.counters() == {}


# ---------------------------------------------------------------------------
# GAE guarantee regressions
# ---------------------------------------------------------------------------

def test_gae_unsatisfiable_raises_typed_error():
    # A zero basis can never span the residual: every refinement step keeps
    # err = ||x - x_r||.  The encoder previously emitted the violating block
    # silently; now it must raise with full diagnostics.
    d = 16
    x = np.ones((3, d), np.float32)
    x_r = np.zeros((3, d), np.float32)
    basis = np.zeros((d, d), np.float32)
    with pytest.raises(GuaranteeUnsatisfiable) as ei:
        gae.gae_encode_blocks(x, x_r, basis, tau=1e-4, bin_size=0.01,
                              max_refine=3)
    e = ei.value
    assert e.err > e.tau and e.tau == pytest.approx(1e-4)
    assert e.max_refine == 3 and 0 <= e.block < 3


def test_gae_encode_never_emits_violating_block():
    # Coarse bin vs tiny tau forces the per-block repair loop (bin_exp > 0);
    # every emitted block must still satisfy the bound.
    rng = np.random.default_rng(1)
    d = 32
    basis = np.linalg.qr(rng.standard_normal((d, d)))[0].astype(np.float32)
    x = rng.standard_normal((20, d)).astype(np.float32)
    x_r = x + 0.3 * rng.standard_normal((20, d)).astype(np.float32)
    tau = 0.05
    out, codes = gae.gae_encode_blocks(x, x_r, basis, tau=tau, bin_size=0.5)
    errs = np.linalg.norm(x - out, axis=1)
    assert np.all(errs <= tau * (1 + 1e-5)), errs.max()
    assert any(c.bin_exp > 0 for c in codes)   # the repair loop really ran
    # decode side reproduces the encoder's corrected output exactly
    dec = gae.gae_decode_blocks(x_r.copy(), basis, codes, bin_size=0.5)
    np.testing.assert_allclose(dec, out, atol=1e-5)


def test_gae_codes_are_ascending_index_order():
    rng = np.random.default_rng(2)
    d = 24
    basis = np.linalg.qr(rng.standard_normal((d, d)))[0].astype(np.float32)
    x = rng.standard_normal((8, d)).astype(np.float32)
    x_r = np.zeros_like(x)
    _, codes = gae.gae_encode_blocks(x, x_r, basis, tau=0.1, bin_size=0.01)
    assert any(c.m > 1 for c in codes)
    for c in codes:
        assert c.indices.size == c.m == c.qcoeffs.size
        assert np.all(np.diff(c.indices) > 0)   # strictly ascending


def test_select_host_matches_device_select():
    rng = np.random.default_rng(3)
    d = 48
    basis = np.linalg.qr(rng.standard_normal((d, d)))[0].astype(np.float32)
    for trial in range(3):
        r = rng.standard_normal((16, d)).astype(np.float32) * (0.2 + trial)
        host = gae.select_host(r, basis, tau=0.3, bin_size=0.02)
        dev = jax.device_get(gae.gae_select(
            jax.numpy.asarray(r), jax.numpy.asarray(basis), 0.3, 0.02))
        np.testing.assert_array_equal(host.m, dev.m)
        np.testing.assert_array_equal(host.ok, dev.ok)
        np.testing.assert_array_equal(host.q_sorted, dev.q_sorted)
        np.testing.assert_allclose(host.corrected, dev.corrected, atol=1e-5)


# ---------------------------------------------------------------------------
# accounting bugfixes
# ---------------------------------------------------------------------------

def test_model_bytes_uses_leaf_dtype_width():
    cfg = CompressorConfig(block_elems=8, k=2)
    comp = HierarchicalCompressor(cfg)
    comp.hbae_params = {"w": np.zeros((4, 4), np.float16)}
    comp.bae_params = [{"w": np.zeros(10, np.float64)}]
    comp.basis = np.zeros((3, 3), np.float32)
    assert comp.model_bytes() == 16 * 2 + 10 * 8 + 9 * 4


def test_compressed_bytes_matches_framing_and_caches(comp_hb):
    comp, hb = comp_hb
    archive = comp.compress(hb, tau=0.5)
    blob = archive_io.serialize_archive(archive)
    assert archive_io.serialized_size(archive) == len(blob)
    assert archive.compressed_bytes() == len(blob)
    assert archive._size_cache == len(blob)          # cached after first query
    assert archive.compressed_bytes() == len(blob)   # stable on re-query
    archive.invalidate_size_cache()
    assert archive._size_cache is None
    assert archive.compressed_bytes() == len(blob)


# ---------------------------------------------------------------------------
# strict vs tolerant decode parity
# ---------------------------------------------------------------------------

def test_nonstrict_decode_bit_identical_on_undamaged_archive(comp_hb):
    comp, hb = comp_hb
    archive = comp.compress(hb, tau=0.5, chunk_hyperblocks=8)
    strict = comp.decompress(archive)
    tolerant, report = comp.decompress(archive, strict=False)
    assert report.ok and not report.damaged
    assert np.array_equal(strict, tolerant)
    # and the same through a full container round-trip
    archive2 = archive_io.deserialize_archive(
        archive_io.serialize_archive(archive))
    tolerant2, report2 = comp.decompress(archive2, strict=False)
    assert report2.ok
    assert np.array_equal(strict, tolerant2)


# ---------------------------------------------------------------------------
# vectorized codec twins vs their scalar oracles
# ---------------------------------------------------------------------------

def test_huffman_vector_decode_matches_scalar():
    rng = np.random.default_rng(4)
    for n in (300, 1000, 5000):   # all above _VECTOR_DECODE_MIN
        vals = rng.geometric(0.3, size=n).astype(np.int64) - 3
        book = entropy.build_huffman(vals)
        data = entropy.huffman_encode(vals, book)
        fast = entropy.huffman_decode(data, book, n)
        slow = entropy.huffman_decode_scalar(data, book, n)
        np.testing.assert_array_equal(fast, slow)
        np.testing.assert_array_equal(fast, vals)


def test_huffman_vector_decode_matches_scalar_on_corruption():
    rng = np.random.default_rng(5)
    vals = rng.geometric(0.4, size=800).astype(np.int64)
    book = entropy.build_huffman(vals)
    data = bytearray(entropy.huffman_encode(vals, book))
    for _ in range(20):
        pos = int(rng.integers(len(data)))
        bit = 1 << int(rng.integers(8))
        data[pos] ^= bit
        fast_err = slow_err = fast = slow = None
        try:
            fast = entropy.huffman_decode(bytes(data), book, 800)
        except (MalformedStream, entropy.TruncatedArchive) as e:
            fast_err = (type(e), str(e))
        try:
            slow = entropy.huffman_decode_scalar(bytes(data), book, 800)
        except (MalformedStream, entropy.TruncatedArchive) as e:
            slow_err = (type(e), str(e))
        assert fast_err == slow_err
        if fast_err is None:
            np.testing.assert_array_equal(fast, slow)
        data[pos] ^= bit   # restore


def test_index_set_codec_roundtrip_with_empty_sets():
    rng = np.random.default_rng(6)
    dim = 80
    sets = []
    for i in range(40):
        if i % 7 == 0:
            sets.append(np.zeros(0, np.int32))
        else:
            m = int(rng.integers(1, dim + 1))
            sets.append(np.sort(rng.choice(dim, size=m,
                                           replace=False)).astype(np.int32))
    blob = entropy.encode_index_sets(sets, dim)
    back = entropy.decode_index_sets(blob, expect_dim=dim)
    assert len(back) == len(sets)
    for a, b in zip(sets, back):
        np.testing.assert_array_equal(a, b)
