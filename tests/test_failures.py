"""ResilientRunner edge cases: retry-budget exhaustion, anomaly rollback,
cold-restore fallback, the preemption hook, data-iterator crash recovery,
and PrefetchIterator worker-death propagation."""
from __future__ import annotations

import time

import jax.numpy as jnp
import pytest

from repro.data.tokens import PrefetchIterator, SyntheticCorpus, \
    TokenPipelineConfig
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.failures import (ResilientRunner, SimulatedDeviceFailure,
                                    chaos_wrap)


def _data_iter(start):
    def gen():
        s = start
        while True:
            yield {"i": s}
            s += 1
    return iter(gen())


def _counting_step(state, batch):
    return state + 1, {"loss": jnp.asarray(0.5)}


def test_retry_budget_exhaustion_reraises(tmp_path):
    """A persistent fault must not loop forever: after max_retries the
    original exception propagates to the caller."""
    def always_fails(state, batch):
        raise SimulatedDeviceFailure("node is gone for good")

    events = []
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(always_fails, mgr, _data_iter, max_retries=2,
                             on_event=lambda k, info: events.append((k, info)))
    with pytest.raises(SimulatedDeviceFailure):
        runner.run(jnp.zeros(()), 0, 5)
    failures = [info for k, info in events if k == "failure"]
    assert len(failures) == 3                      # max_retries + final raise
    assert failures[-1]["retry"] == 3


def test_anomaly_restore_policy_rolls_back(tmp_path):
    """anomaly_policy='restore': a loss spike rolls the state back to the
    newest checkpoint instead of skipping the batch."""
    spiked = {"done": False}

    def step_fn(state, batch):
        loss = 0.5
        if int(batch["i"]) == 3 and not spiked["done"]:
            spiked["done"] = True
            loss = float("nan")
        return state + 1, {"loss": jnp.asarray(loss)}

    events = []
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(step_fn, mgr, _data_iter, save_every=2,
                             anomaly_policy="restore",
                             on_event=lambda k, info: events.append((k, info)))
    state, end = runner.run(jnp.zeros(()), 0, 6)
    assert end == 6
    assert runner.stats.restores == 1
    assert runner.stats.skipped_batches == 0
    anomalies = [info for k, info in events if k == "anomaly"]
    restores = [info for k, info in events if k == "restore"]
    assert len(anomalies) == 1 and anomalies[0]["step"] == 3
    assert restores == [{"step": 2}]               # rolled back to save_every=2
    # state advanced exactly once per *kept* step after the rollback
    assert int(state) == 6


def test_cold_restore_fallback_without_checkpoint(tmp_path):
    """Crash before any checkpoint exists: runner falls back to the caller's
    initial state at step 0 (cold restore) and still completes."""
    events = []
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(chaos_wrap(_counting_step, {1}), mgr, _data_iter,
                             save_every=100, max_retries=3,
                             on_event=lambda k, info: events.append((k, info)))
    state, end = runner.run(jnp.zeros(()), 0, 4)
    assert end == 4
    restores = [info for k, info in events if k == "restore"]
    assert restores == [{"step": 0, "cold": True}]
    assert runner.stats.restores == 1
    # cold fallback keeps the in-memory state at crash time (best effort), so
    # the surviving pre-crash step is applied once more on replay: 1 + 4
    assert int(state) == 5


def test_preemption_checkpoints_and_stops(tmp_path):
    """request_preemption() (the SIGTERM hook) stops at the next boundary and
    leaves a blocking checkpoint behind."""
    runner_box = {}

    def step_fn(state, batch):
        if int(batch["i"]) == 2:
            runner_box["r"].request_preemption()
        return state + 1, {"loss": jnp.asarray(0.5)}

    events = []
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(step_fn, mgr, _data_iter, save_every=100,
                             on_event=lambda k, info: events.append((k, info)))
    runner_box["r"] = runner
    state, end = runner.run(jnp.zeros(()), 0, 10)
    assert end == 3                                # stopped early, not at 10
    assert ("preempted", {"step": 3}) in events
    assert mgr.latest_step() == 3
    step, back = mgr.restore()
    assert step == 3 and int(back) == 3


def test_data_iterator_crash_counts_as_step_failure(tmp_path):
    """next(data) raising inside the step loop must ride the recovery path
    (restore + iterator rebuild), not escape the runner."""
    crashed = {"done": False}

    def make_iter(start):
        def gen():
            s = start
            while True:
                if s == 2 and not crashed["done"]:
                    crashed["done"] = True
                    raise RuntimeError("prefetch worker died")
                yield {"i": s}
                s += 1
        return iter(gen())

    events = []
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    runner = ResilientRunner(_counting_step, mgr, make_iter, save_every=100,
                             max_retries=3,
                             on_event=lambda k, info: events.append((k, info)))
    state, end = runner.run(jnp.zeros(()), 0, 4)
    assert end == 4
    failures = [info for k, info in events if k == "failure"]
    assert len(failures) == 1
    assert "prefetch worker died" in failures[0]["error"]
    assert runner.stats.restores == 1              # recovered, not re-raised


def _token_cfg(**kw):
    return TokenPipelineConfig(vocab=50, seq_len=8, global_batch=4, **kw)


def test_prefetch_iterator_propagates_worker_crash():
    corpus = SyntheticCorpus(_token_cfg())
    boom = {"n": 0}
    real = corpus.batch_at

    def crashing(step):
        boom["n"] += 1
        if step >= 2:
            raise ValueError("corrupt shard")
        return real(step)

    corpus.batch_at = crashing
    it = PrefetchIterator(corpus, start_step=0, depth=2)
    try:
        assert next(it)["tokens"].shape == (4, 8)      # steps 0..1 are fine
        assert next(it)["tokens"].shape == (4, 8)
        with pytest.raises(ValueError, match="corrupt shard"):
            for _ in range(4):                          # must NOT block
                next(it)
        # the iterator stays poisoned: the error re-raises, never hangs
        with pytest.raises(ValueError, match="corrupt shard"):
            next(it)
    finally:
        it.close()
    assert not it._thread.is_alive()                    # close() joins


def test_prefetch_iterator_close_joins_blocked_worker():
    it = PrefetchIterator(SyntheticCorpus(_token_cfg()), start_step=0,
                          depth=1)
    # let the worker fill the queue and block on put()
    deadline = time.monotonic() + 5.0
    while it.q.empty() and time.monotonic() < deadline:
        time.sleep(0.005)
    it.close()
    assert not it._thread.is_alive()
    with pytest.raises(StopIteration):
        next(it)
    it.close()                                          # idempotent
