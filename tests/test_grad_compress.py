"""Gradient compression: linearity under aggregation, error feedback,
quantization, the tau-bounded GAE mode, and end-to-end LM training parity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime import grad_compress


def _g(seed, shape=(64, 48)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def test_projection_is_linear_across_workers():
    """mean(U^T g_i) == U^T mean(g_i) — the property that makes the
    compressed all-reduce exact for the projected component."""
    basis = grad_compress.make_basis(32, 8)
    gs = [np.asarray(_g(i, (4, 32))) for i in range(4)]
    cs = [g @ np.asarray(basis) for g in gs]
    np.testing.assert_allclose(np.mean(cs, axis=0),
                               np.mean(gs, axis=0) @ np.asarray(basis),
                               atol=1e-5)


def test_basis_is_orthonormal_and_deterministic():
    u1 = np.asarray(grad_compress.make_basis(128, 16, seed=5))
    u2 = np.asarray(grad_compress.make_basis(128, 16, seed=5))
    np.testing.assert_array_equal(u1, u2)
    np.testing.assert_allclose(u1.T @ u1, np.eye(16), atol=1e-5)


def test_compression_ratio_accounting():
    g = {"w": _g(0, (512, 256))}
    st = grad_compress.init_state(g, block=256, rank=16)
    _, _, stats = grad_compress.compress_update(g, st, refresh_every=0)
    assert abs(float(stats["compression"]) - 256 / 16) < 0.5
    # with refresh, the amortized covariance psum is accounted too
    _, _, stats = grad_compress.compress_update(g, st, refresh_every=50)
    assert float(stats["compression"]) < 256 / 16


def test_fixed_basis_error_feedback_diverges():
    """Design-motivating failure mode: with a FIXED random basis, the gradient
    component orthogonal to its span is never transmitted and the EF buffer
    grows linearly — this is why the adaptive refresh exists."""
    g = {"w": _g(1, (8, 64))}
    st = grad_compress.init_state(g, block=64, rank=4)
    for _ in range(30):
        _, st, _ = grad_compress.compress_update(g, st, refresh_every=0)
    assert float(jnp.linalg.norm(st.error["w"])) > \
        5 * float(jnp.linalg.norm(g["w"]))


def test_adaptive_refresh_bounds_error_feedback():
    """With the paper's distributed-PCA basis refresh, persistent gradient
    structure enters the basis: a low-rank gradient is captured exactly (EF
    collapses) and a full-rank one stays BOUNDED (vs divergence above)."""
    # rank-2 gradient, rank-4 basis -> refresh captures it fully
    a = _g(1, (8, 2))
    b = _g(2, (2, 64))
    g = {"w": a @ b}
    st = grad_compress.init_state(g, block=64, rank=4)
    for _ in range(3):
        _, st, _ = grad_compress.compress_update(g, st, refresh_every=1)
    assert float(jnp.linalg.norm(st.error["w"])) < \
        1e-3 * float(jnp.linalg.norm(g["w"]))

    # full-rank gradient, rank-deficient basis -> bounded (no divergence)
    g2 = {"w": _g(1, (8, 64))}
    st2 = grad_compress.init_state(g2, block=64, rank=4)
    for _ in range(30):
        _, st2, _ = grad_compress.compress_update(g2, st2, refresh_every=1)
    assert float(jnp.linalg.norm(st2.error["w"])) < \
        float(jnp.linalg.norm(g2["w"]))


def test_quantized_coefficients_path():
    g = {"w": _g(2, (16, 128))}
    st = grad_compress.init_state(g, block=128, rank=32)
    ghat, st2, _ = grad_compress.compress_update(g, st, bin_size=0.01)
    # exact split invariant holds with quantization too
    np.testing.assert_allclose(np.asarray(ghat["w"] + st2.error["w"]),
                               np.asarray(g["w"]), atol=1e-5)


def test_gae_mode_guarantees_block_bound():
    g = {"w": _g(3, (40, 256))}
    tau = 0.3
    bounded, stats = grad_compress.gae_compress_grads(g, tau=tau, block=256)
    errs = np.linalg.norm(np.asarray(g["w"] - bounded["w"]).reshape(-1, 256),
                          axis=1)
    assert errs.max() <= tau * (1 + 1e-4)
    assert 0.0 < float(stats["keep_frac"]) <= 1.0


def test_lm_training_with_compression_converges():
    """Compressed-gradient training tracks dense training on a tiny LM."""
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.models.registry import reduced_config
    from repro.train import optim
    from repro.train.loop import init_train_state, make_train_step

    cfg = reduced_config(get_config("qwen2-1.5b"))
    finals = {}
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (16, 4, 32)).astype(np.int32)
    for mode in ("none", "pca_ef"):
        run = RunConfig(gradient_compression=mode, grad_comp_rank=32)
        opt = optim.adam(2e-3)
        state = init_train_state(jax.random.PRNGKey(0), cfg, run, opt)
        step = jax.jit(make_train_step(cfg, run, opt))
        losses = []
        for i in range(16):
            batch = {"tokens": jnp.asarray(toks[i]),
                     "labels": jnp.asarray(toks[i])}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        finals[mode] = losses
    assert finals["none"][-1] < finals["none"][0]          # both learn
    assert finals["pca_ef"][-1] < finals["pca_ef"][0]
    # compressed stays within 30% of dense at the last step
    assert finals["pca_ef"][-1] < finals["none"][-1] * 1.3 + 0.5
