"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel in ``repro.kernels`` is validated against its ``ref.py`` across
shapes (tile-aligned and ragged), dtypes, and feature flags (causal/window/
GQA groups/heads/chunk sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_attention import ops as ba_ops
from repro.kernels.block_attention import ref as ba_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.gae_project import ops as gp_ops
from repro.kernels.gae_project import ref as gp_ref
from repro.kernels.quantize import ops as qz_ops
from repro.kernels.quantize import ref as qz_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,t,h,kv,hd", [
    (2, 256, 256, 4, 2, 64),      # tile-aligned GQA
    (1, 200, 200, 8, 1, 32),      # ragged seq, MQA
    (2, 128, 128, 4, 4, 128),     # MHA, wide head
    (1, 64, 192, 2, 2, 16),       # t > s: suffix-aligned queries
    (1, 96, 96, 6, 3, 48),        # ragged everything
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(b, s, t, h, kv, hd, causal, window):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + hd + window), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, hd), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    exp = fa_ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), **_tol(q.dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True)
    exp = fa_ref.flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_small_block_sizes():
    """Multi-block online-softmax path (several kv iterations)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    exp = fa_ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# hyper-block attention (HBAE)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n,d,heads", [
    (37, 10, 128, 1),     # paper config: k=10 blocks, d=128, single head
    (256, 8, 64, 4),
    (5, 5, 32, 2),
    (1, 2, 16, 1),
    (300, 16, 128, 8),
])
def test_block_attention_sweep(b, n, d, heads):
    ks = jax.random.split(jax.random.fold_in(KEY, b * n + d), 3)
    q, k, v = (jax.random.normal(kk, (b, n, d), jnp.float32) for kk in ks)
    out = ba_ops.block_attention(q, k, v, heads=heads)
    exp = ba_ref.block_attention_ref(q, k, v, heads=heads)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5,
                               rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_attention_dtype_and_lead_shape(dtype):
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (4, 9, 10, 64)).astype(dtype) for kk in ks)
    out = ba_ops.block_attention(q, k, v, heads=1)
    exp = ba_ref.block_attention_ref(q.reshape(36, 10, 64),
                                     k.reshape(36, 10, 64),
                                     v.reshape(36, 10, 64), heads=1)
    assert out.shape == (4, 9, 10, 64)
    np.testing.assert_allclose(np.asarray(out.reshape(36, 10, 64), np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# GAE projection
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [
    (100, 80),        # paper S3D GAE block 5*4*4=80
    (64, 256),        # E3SM GAE block 16*16
    (1024, 1521),     # XGC 39*39 (column-tiled basis path)
    (7, 9),           # tiny ragged
    (512, 512),       # tile-exact
])
def test_gae_project_sweep(n, d):
    ks = jax.random.split(jax.random.fold_in(KEY, n + d), 2)
    r = jax.random.normal(ks[0], (n, d), jnp.float32)
    u = jax.random.normal(ks[1], (d, d), jnp.float32) / np.sqrt(d)
    c, c2 = gp_ops.gae_project(r, u)
    ce, c2e = gp_ref.gae_project_ref(r, u)
    np.testing.assert_allclose(np.asarray(c), np.asarray(ce), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c2e), atol=1e-4, rtol=1e-4)


def test_gae_project_matches_gae_select_path():
    """The kernel path inside gae_select must agree with the jnp path."""
    from repro.core.gae import fit_pca_basis, gae_select
    ks = jax.random.split(KEY, 2)
    r = jax.random.normal(ks[0], (50, 40), jnp.float32) * 0.1
    basis = fit_pca_basis(r)
    a = gae_select(r, basis, tau=0.05, bin_size=0.01, use_kernel=False)
    b = gae_select(r, basis, tau=0.05, bin_size=0.01, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    np.testing.assert_allclose(np.asarray(a.corrected), np.asarray(b.corrected),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1000,), (64, 33, 7), (2, 3), (4096,)])
@pytest.mark.parametrize("bin_size", [0.005, 0.1, 0.5])
def test_quantize_sweep(shape, bin_size):
    x = jax.random.normal(jax.random.fold_in(KEY, shape[0] + int(bin_size * 1e3)),
                          shape, jnp.float32)
    q, deq, err2 = qz_ops.quantize_fused(x, bin_size)
    qe, deqe, err2e = qz_ref.quantize_fused_ref(x, bin_size)
    # values landing exactly on a bin boundary may flip by one bin between
    # the kernel's true division and XLA's multiply-by-reciprocal — both are
    # valid round-to-nearest results within bin/2 of x.
    dq = np.abs(np.asarray(q, np.int64) - np.asarray(qe, np.int64))
    assert dq.max() <= 1 and (dq != 0).mean() < 1e-3
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x),
                               atol=bin_size * 0.500001)
    assert float(np.max(err2)) <= (bin_size / 2) ** 2 * 1.0001


def test_quantize_matches_core_quantization():
    from repro.core.quantization import dequantize, quantize
    x = jax.random.normal(KEY, (257,), jnp.float32)
    q, deq, _ = qz_ops.quantize_fused(x, 0.01)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(quantize(x, 0.01)))
    np.testing.assert_allclose(np.asarray(deq),
                               np.asarray(dequantize(quantize(x, 0.01), 0.01)),
                               atol=1e-7)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 64, 4, 16, 1, 8, 16),
    (1, 100, 2, 8, 2, 4, 32),     # ragged seq (padded path)
    (1, 128, 8, 32, 1, 16, 64),
    (3, 32, 2, 64, 2, 128, 16),   # fat state
])
def test_ssd_scan_sweep(b, s, h, p, g, n, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + p + n), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    a_log = jax.random.uniform(ks[2], (h,), jnp.float32, 0.0, 1.0)
    bb = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
    y, st = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=chunk)
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ye, ste = ssd_ref.ssd_scan_ref(x, dt, a_log, bb, cc, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye[:, :s]), atol=3e-4,
                               rtol=3e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ste), atol=3e-4,
                               rtol=3e-4)


def test_ssd_scan_matches_model_ref():
    """Kernel oracle == the model's own ssd_ref (two independent paths)."""
    from repro.models.ssd import ssd_ref as model_ref
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, 64, 4, 16), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 64, 4), jnp.float32))
    a_log = jax.random.uniform(ks[2], (4,), jnp.float32, 0.0, 1.0)
    bb = jax.random.normal(ks[3], (2, 64, 1, 8), jnp.float32)
    cc = jax.random.normal(ks[4], (2, 64, 1, 8), jnp.float32)
    y1, s1 = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=16)
    y2, s2 = model_ref(x, dt, a_log, bb, cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-4, rtol=3e-4)


def test_ssd_decode_consistency_with_scan():
    """Step-by-step decode must reproduce the chunked scan's final state."""
    from repro.models.ssd import ssd_decode_step
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 1, 16, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    a_log = jax.random.uniform(ks[2], (h,), jnp.float32, 0.0, 1.0)
    bb = jax.random.normal(ks[3], (b, s, 1, n), jnp.float32)
    cc = jax.random.normal(ks[4], (b, s, 1, n), jnp.float32)
    _, st_scan = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=8)
    hstate = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, hstate = ssd_decode_step(hstate, x[:, t], dt[:, t], a_log,
                                    bb[:, t], cc[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(hstate), np.asarray(st_scan),
                               atol=3e-4, rtol=3e-4)
    y_scan, _ = ssd_ops.ssd(x, dt, a_log, bb, cc, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, axis=1)),
                               np.asarray(y_scan), atol=3e-4, rtol=3e-4)
