"""Mesh-sharded stage pipeline + CompressOptions surface.

Two layers:

* in-process tests — option validation, the resolve_options shim, shard
  group planning, and single-device equivalence of the options surface
  (these run on however many devices the test process happens to have);
* the multi-device parity gate — ``repro.parallel.mesh_check`` run as a
  SUBPROCESS, because ``--xla_force_host_platform_device_count`` is frozen
  at first jax import and pytest has long since imported jax.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core.errors import ConfigError
from repro.core.options import MESH_AXIS, CompressOptions, resolve_options
from repro.parallel import mesh_exec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- CompressOptions validation ----------------------------------------------

def test_options_defaults_are_valid():
    opts = CompressOptions()
    assert opts.tau is None
    assert opts.chunk_hyperblocks == 64
    assert not opts.fault_tolerant()
    assert opts.mesh_shards() == 0


@pytest.mark.parametrize("kw", [
    {"chunk_hyperblocks": 0},
    {"chunk_hyperblocks": -3},
    {"chunk_hyperblocks": 2.5},
    {"chunk_hyperblocks": True},
    {"tau": 0.0},
    {"tau": -1.0},
    {"queue_depth": 0},
    {"retries": -1},
    {"stage_deadline_s": 0.0},
    {"mesh": 0},
    {"mesh": -2},
    {"mesh": True},
    {"mesh": "four"},
])
def test_options_reject_bad_configs(kw):
    with pytest.raises(ConfigError):
        CompressOptions(**kw)


def test_options_reject_mesh_without_hb_axis():
    class FakeMesh:
        axis_names = ("x",)
        shape = {"x": 4}
    with pytest.raises(ConfigError, match=MESH_AXIS):
        CompressOptions(mesh=FakeMesh())


def test_options_reject_mesh_sharding_other_axes():
    class FakeMesh:
        axis_names = (MESH_AXIS, "model")
        shape = {MESH_AXIS: 2, "model": 2}
    with pytest.raises(ConfigError, match="model"):
        CompressOptions(mesh=FakeMesh())


def test_options_accept_mesh_with_aux_size1_axes():
    class FakeMesh:
        axis_names = (MESH_AXIS, "aux")
        shape = {MESH_AXIS: 4, "aux": 1}
    opts = CompressOptions(mesh=FakeMesh())
    assert opts.mesh_shards() == 4


def test_options_replace_revalidates():
    opts = CompressOptions(tau=0.5)
    assert opts.replace(tau=1.0).tau == 1.0
    with pytest.raises(ConfigError):
        opts.replace(chunk_hyperblocks=0)


def test_options_fault_tolerant_views():
    assert CompressOptions(retries=2).fault_tolerant()
    assert CompressOptions(stage_deadline_s=1.0).fault_tolerant()
    assert CompressOptions(chaos_seed=7).fault_tolerant()
    assert not CompressOptions(tau=0.5).fault_tolerant()
    assert CompressOptions(mesh=3).mesh_shards() == 3


# -- resolve_options shim -----------------------------------------------------

def test_resolve_options_passthrough():
    opts = CompressOptions(tau=0.5)
    assert resolve_options(opts, {}, caller="t") is opts


def test_resolve_options_rejects_both_surfaces():
    with pytest.raises(ConfigError, match="not both"):
        resolve_options(CompressOptions(), {"tau": 0.5}, caller="t")


def test_resolve_options_legacy_warns_once():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        opts = resolve_options(None, {"tau": 0.5, "chunk_hyperblocks": 8},
                               caller="t")
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "deprecated" in str(dep[0].message)
    assert opts.tau == 0.5 and opts.chunk_hyperblocks == 8


def test_resolve_options_no_args_no_warning():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        opts = resolve_options(None, {}, caller="t")
    assert not caught
    assert opts == CompressOptions()


# -- shard group planning -----------------------------------------------------

def _spans(widths):
    """Consecutive (start, width) spans — the pipeline's stripe tiling."""
    spans, start = [], 0
    for w in widths:
        spans.append((start, w))
        start += w
    return spans


def test_plan_shard_groups_all_aligned():
    groups, tail = mesh_exec.plan_shard_groups(_spans([4, 4, 4, 4]), 2)
    assert len(groups) == 2 and tail == []
    assert mesh_exec.group_slice(groups[0]) == (0, 8)
    assert mesh_exec.group_slice(groups[1]) == (8, 16)


def test_plan_shard_groups_ragged_tail():
    spans = _spans([4, 4, 4, 4, 4, 2])
    groups, tail = mesh_exec.plan_shard_groups(spans, 4)
    assert len(groups) == 1
    assert tail == spans[4:]


def test_plan_shard_groups_unequal_widths_stop_grouping():
    # widths diverge inside the second candidate group: everything from
    # there on takes the per-stripe path
    spans = _spans([4, 4, 4, 3, 4, 4])
    groups, tail = mesh_exec.plan_shard_groups(spans, 2)
    assert len(groups) == 1
    assert tail == spans[2:]


def test_plan_shard_groups_fewer_spans_than_shards():
    spans = _spans([4, 4])
    groups, tail = mesh_exec.plan_shard_groups(spans, 4)
    assert groups == [] and tail == spans


def test_resolve_mesh_trivial_specs():
    assert mesh_exec.resolve_mesh(None) is None
    assert mesh_exec.resolve_mesh(1) is None


def test_make_compress_mesh_rejects_impossible():
    with pytest.raises(ConfigError):
        mesh_exec.make_compress_mesh(0)
    with pytest.raises(ConfigError):
        mesh_exec.make_compress_mesh(10 ** 6)


# -- options surface equivalence (single device) ------------------------------

def test_compress_options_equals_legacy_kwargs(comp_hb):
    from repro.runtime import archive_io
    comp, hb = comp_hb
    via_opts = comp.compress(
        hb, options=CompressOptions(tau=0.5, chunk_hyperblocks=8))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        via_legacy = comp.compress(hb, tau=0.5, chunk_hyperblocks=8)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert archive_io.serialize_archive(via_opts) == \
        archive_io.serialize_archive(via_legacy)


def test_compress_rejects_mixed_surfaces(comp_hb):
    comp, hb = comp_hb
    with pytest.raises(ConfigError, match="not both"):
        comp.compress(hb, tau=0.5, options=CompressOptions(tau=0.5))


def test_mesh1_options_byte_identical_to_unsharded(comp_hb):
    """mesh=1 resolves to no mesh at all — same programs, same bytes."""
    from repro.runtime import archive_io
    comp, hb = comp_hb
    opts = CompressOptions(tau=0.5, chunk_hyperblocks=8)
    a = comp.compress(hb, options=opts)
    b = comp.compress(hb, options=opts.replace(mesh=1))
    assert archive_io.serialize_archive(a) == archive_io.serialize_archive(b)


@pytest.fixture(scope="module")
def comp_hb():
    import jax
    from repro.core import CompressorConfig, HierarchicalCompressor
    from repro.core import bae as bae_mod
    from repro.core import hbae as hbae_mod
    cfg = CompressorConfig(block_elems=40, k=2, emb=16, hidden=32,
                           hb_latent=8, bae_hidden=32, bae_latent=4,
                           gae_block_elems=80, hb_bin=0.01, bae_bin=0.01,
                           gae_bin=0.02)
    comp = HierarchicalCompressor(cfg)
    khb, kb = jax.random.split(jax.random.PRNGKey(0))
    comp.hbae_params = hbae_mod.hbae_init(
        khb, in_dim=cfg.block_elems, k=cfg.k, emb=cfg.emb, hidden=cfg.hidden,
        latent=cfg.hb_latent, heads=cfg.heads)
    comp.bae_params = [bae_mod.bae_init(kb, in_dim=cfg.block_elems,
                                        hidden=cfg.bae_hidden,
                                        latent=cfg.bae_latent)]
    rng = np.random.default_rng(0)
    hb = 0.1 * rng.standard_normal(
        (24, cfg.k, cfg.block_elems)).astype(np.float32)
    comp.fit_basis(hb)
    return comp, hb


# -- the multi-device parity gate (subprocess) --------------------------------

def test_mesh_check_subprocess_four_devices():
    """Full sharded-vs-single parity suite under 4 virtual devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)      # mesh_check sets its own
    env["REPRO_MESH_CHECK_DEVICES"] = "4"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.parallel.mesh_check"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert proc.returncode == 0, \
        f"mesh_check failed:\n{proc.stdout}\n{proc.stderr}"
    report = json.loads(proc.stdout)
    assert report["ok"]
    assert report["devices"] >= 4
    names = {c["name"] for c in report["checks"]}
    assert {"batch_parity", "stream_parity", "zero_retraces_after_warmup",
            "psum_basis_consistent", "sharded_decompress",
            "options_shim"} <= names
