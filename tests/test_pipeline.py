"""End-to-end compressor pipeline tests (paper Fig. 1 path)."""
import numpy as np
import pytest

from repro.core import CompressorConfig, HierarchicalCompressor
from repro.data import blocks as blocks_mod
from repro.data import synthetic


@pytest.fixture(scope="module")
def s3d_small():
    # tiny S3D-like cube: 8 species, 10 steps, 16x16 grid
    data = synthetic.s3d_like(n_species=8, t=10, h=16, w=16, seed=0)
    norm = blocks_mod.Normalizer.fit(data, mode="range", axis=0)
    return norm.forward(data)


@pytest.fixture(scope="module")
def fitted(s3d_small):
    # block (8,5,4,4) like the paper (species,t,y,x); hyper-blocks of k=2
    blocks, meta = blocks_mod.block_nd(s3d_small, (8, 5, 4, 4))
    hb = blocks_mod.group_hyperblocks(blocks, k=2)
    cfg = CompressorConfig(block_elems=blocks.shape[1], k=2, emb=32, hidden=64,
                           hb_latent=16, bae_latent=8, gae_block_elems=80,
                           epochs_hbae=15, epochs_bae=10, batch=16,
                           hb_bin=0.01, bae_bin=0.01, gae_bin=0.02)
    comp = HierarchicalCompressor(cfg).fit(hb, seed=0)
    return comp, hb, blocks, meta


def test_blocking_roundtrip(s3d_small):
    blocks, meta = blocks_mod.block_nd(s3d_small, (8, 5, 4, 4))
    back = blocks_mod.unblock_nd(blocks, meta)
    np.testing.assert_array_equal(back, s3d_small)


def test_hyperblock_roundtrip(s3d_small):
    blocks, _ = blocks_mod.block_nd(s3d_small, (8, 5, 4, 4))
    hb = blocks_mod.group_hyperblocks(blocks, 2)
    np.testing.assert_array_equal(blocks_mod.ungroup_hyperblocks(hb), blocks)


def test_compress_decompress_roundtrip_no_gae(fitted):
    comp, hb, _, _ = fitted
    archive = comp.compress(hb, tau=None)
    recon = comp.decompress(archive)
    assert recon.shape == hb.shape
    assert np.isfinite(recon).all()
    assert archive.compression_ratio() > 1.0


def test_gae_guarantee_end_to_end(fitted):
    comp, hb, _, _ = fitted
    tau = 0.25
    archive = comp.compress(hb, tau=tau)
    recon = comp.decompress(archive)
    d_gae = comp.cfg.gae_block_elems
    x = hb.reshape(-1, d_gae)
    r = recon.reshape(-1, d_gae)
    errs = np.linalg.norm(x - r, axis=1)
    assert np.all(errs <= tau + 1e-4), errs.max()


def test_tighter_tau_costs_more_bytes(fitted):
    comp, hb, _, _ = fitted
    loose = comp.compress(hb, tau=0.5).compressed_bytes()
    tight = comp.compress(hb, tau=0.05).compressed_bytes()
    assert tight > loose


def test_archive_accounting(fitted):
    comp, hb, _, _ = fitted
    archive = comp.compress(hb, tau=0.25)
    assert archive.n_values == hb.size
    assert archive.compressed_bytes() > 0
    assert archive.compression_ratio(include_model_bytes=comp.model_bytes()) < \
        archive.compression_ratio()


def test_save_load_roundtrip(fitted, tmp_path):
    comp, hb, _, _ = fitted
    p = str(tmp_path / "comp.pkl")
    comp.save(p)
    comp2 = HierarchicalCompressor.load(p)
    a1 = comp.compress(hb, tau=0.25)
    a2 = comp2.compress(hb, tau=0.25)
    np.testing.assert_allclose(comp.decompress(a1), comp2.decompress(a2),
                               atol=1e-6)


def test_normalizer_roundtrip():
    data = synthetic.e3sm_like(t=12, h=16, w=32, seed=1)
    nz = blocks_mod.Normalizer.fit(data, mode="zscore")
    np.testing.assert_allclose(nz.inverse(nz.forward(data)), data, rtol=1e-4,
                               atol=1e-3)
