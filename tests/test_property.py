"""Property-based tests (hypothesis) on the system's core invariants.

The paper's central claim is an INVARIANT, not a benchmark: after GAE
post-processing every block satisfies ||x - x^G||_2 <= tau, for any data, any
basis quality, any tau, any bin size.  These tests attack it with adversarial
inputs, plus the supporting algebraic invariants the pipeline relies on
(one-shot selection == Algorithm 1, quantization error bounds, bitstream
round-trips, blocking round-trips).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_fallback import given, settings, st

from repro.core import entropy, gae
from repro.core.quantization import (dequantize, quantization_error_bound,
                                     quantize)

_sizes = st.tuples(st.integers(2, 24), st.integers(2, 48))   # (N blocks, D)


def _blocks(draw, n, d, scale):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    kind = draw(st.sampled_from(["gauss", "outliers", "lowrank", "const"]))
    if kind == "gauss":
        x = rng.standard_normal((n, d))
    elif kind == "outliers":
        x = rng.standard_normal((n, d))
        x[rng.integers(0, n, 3), rng.integers(0, d, 3)] *= 100.0
    elif kind == "lowrank":
        x = rng.standard_normal((n, 2)) @ rng.standard_normal((2, d))
    else:
        x = np.ones((n, d)) * rng.uniform(-5, 5)
    return (scale * x).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_gae_guarantee_holds_for_any_input(data):
    """THE invariant: per-block l2 error <= tau after GAE encode/decode."""
    n, d = data.draw(_sizes)
    x = _blocks(data.draw, n, d, scale=data.draw(st.floats(0.01, 10.0)))
    x_r = x + _blocks(data.draw, n, d, scale=0.3)       # bad reconstruction
    tau = data.draw(st.floats(0.05, 2.0))
    bin_size = data.draw(st.floats(1e-4, 0.5))
    basis = np.asarray(gae.fit_pca_basis(jnp.asarray(x - x_r)))
    out, codes = gae.gae_encode_blocks(x, x_r, basis, tau, bin_size)
    errs = np.linalg.norm(x - out, axis=1)
    assert errs.max() <= tau * (1 + 1e-5), (errs.max(), tau)
    # decode path reproduces the encoder's reconstruction exactly
    dec = gae.gae_decode_blocks(x_r, basis, codes,  bin_size)
    np.testing.assert_allclose(dec, out, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_one_shot_selection_matches_algorithm1(data):
    """gae_select (batched, branch-free) == the paper's serial Algorithm 1."""
    n, d = data.draw(st.tuples(st.integers(2, 12), st.integers(2, 24)))
    x = _blocks(data.draw, n, d, scale=1.0)
    x_r = x + _blocks(data.draw, n, d, scale=0.2)
    tau = data.draw(st.floats(0.1, 1.0))
    bin_size = data.draw(st.floats(1e-3, 0.05))
    basis = np.asarray(gae.fit_pca_basis(jnp.asarray(x - x_r)))
    ref_out, ref_ms = gae.gae_reference_loop(x, x_r, basis, tau, bin_size)
    sel = gae.gae_select(jnp.asarray(x - x_r), jnp.asarray(basis), tau, bin_size)
    # same minimal M wherever Algorithm 1 terminated within D
    m = np.asarray(sel.m)
    for i in range(n):
        if ref_ms[i] < d:
            assert m[i] == ref_ms[i], (i, m[i], ref_ms[i])
    ref_err = np.linalg.norm(x - ref_out, axis=1)
    sel_err = np.asarray(sel.err)
    np.testing.assert_allclose(sel_err, ref_err, atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-4, 10.0), st.integers(1, 4096),
       st.floats(-1e4, 1e4))
def test_quantization_error_bound(bin_size, n, val):
    """|x - deq(q(x))| <= bin/2 + fp32 ulp slack elementwise.

    The exact-arithmetic bound is bin/2; in fp32, when |x|/bin > 2^24 the
    dequantized product q*bin itself rounds (ulp(|x|) error) — the GAE
    encoder is immune because it verifies REALIZED error, but the
    theoretical bound needs the ulp term."""
    x = jnp.full((n,), val, jnp.float32)
    err = jnp.abs(x - dequantize(quantize(x, bin_size), bin_size))
    ulp = abs(val) * 2.0 ** -23 * 4
    assert float(err.max()) <= bin_size * 0.5 + 1e-3 * bin_size + ulp
    assert float(jnp.linalg.norm(err)) <= \
        quantization_error_bound(bin_size, n) * (1 + 1e-3) + ulp * n ** 0.5


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-5000, 5000), min_size=1, max_size=2000))
def test_huffman_roundtrip(values):
    arr = np.asarray(values, np.int64)
    stream = entropy.huffman_compress(arr)
    np.testing.assert_array_equal(entropy.huffman_decompress(stream), arr)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_index_bitmask_roundtrip(data):
    dim = data.draw(st.integers(1, 200))
    n_sets = data.draw(st.integers(0, 20))
    sets = []
    for _ in range(n_sets):
        k = data.draw(st.integers(0, dim))
        idx = np.sort(np.random.default_rng(
            data.draw(st.integers(0, 1000))).permutation(dim)[:k]).astype(np.int32)
        sets.append(idx)
    blob = entropy.encode_index_sets(sets, dim)
    out = entropy.decode_index_sets(blob)
    assert len(out) == len(sets)
    for a, b in zip(sets, out):
        np.testing.assert_array_equal(np.sort(a), b)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_blocking_roundtrip_any_divisible_shape(data):
    from repro.data.blocks import block_nd, unblock_nd
    dims = data.draw(st.integers(1, 3))
    shape, bshape = [], []
    for _ in range(dims):
        b = data.draw(st.integers(1, 4))
        m = data.draw(st.integers(1, 4))
        shape.append(b * m)
        bshape.append(b)
    rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
    x = rng.standard_normal(shape).astype(np.float32)
    blocks, meta = block_nd(x, bshape)
    np.testing.assert_array_equal(unblock_nd(blocks, meta), x)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
def test_grad_compress_error_feedback_is_lossless_in_the_limit(seed, rank):
    """EF invariant: compressed + error buffer == input (exact split)."""
    from repro.runtime import grad_compress
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((8, 96)).astype(np.float32))}
    st_ = grad_compress.init_state(g, block=32, rank=min(rank, 32))
    ghat, new_st, _ = grad_compress.compress_update(g, st_)
    # ghat + error == g exactly (up to fp) when bin_size == 0
    total = ghat["w"] + new_st.error["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]),
                               atol=1e-5)
