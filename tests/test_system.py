"""End-to-end system behaviour: the paper's pipeline against the reference
compressors, the serving engine, the token pipeline contract, pipeline
parallelism, KV compression, and the dry-run machinery at host scale."""
from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models.registry import get_model, reduced_config


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    run = RunConfig()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg, run)
    engine = ServeEngine(cfg, run, params, batch_size=2, max_len=64, seed=0)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new_tokens=4 + i % 3) for i in range(5)]
    outs = engine.serve(reqs)
    assert [c.rid for c in outs] == [0, 1, 2, 3, 4]
    for c in outs:
        assert len(c.tokens) == reqs[c.rid].max_new_tokens
        assert np.all((c.tokens >= 0) & (c.tokens < cfg.vocab))


def test_serve_kv_compression_bounded_drift():
    """Generation with bounded-KV compression agrees with raw KV for a
    reasonably tight tau (the guarantee bounds the attention perturbation)."""
    from repro.serve.engine import ServeEngine
    cfg = reduced_config(get_config("qwen3-1.7b"))
    run = RunConfig()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(1), cfg, run)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, (2, 12)).astype(np.int32)
    outs = {}
    for tau in (None, 0.01):
        engine = ServeEngine(cfg, run, params, batch_size=2, max_len=48,
                             kv_tau=tau, seed=0)
        outs[tau] = engine.generate_batch(prompts, max_new=6)
    agree = np.mean(outs[None] == outs[0.01])
    assert agree >= 0.5, agree   # tight tau -> mostly identical decoding


def test_serve_whisper_with_frames_frontend():
    """Enc-dec serving: requests carry precomputed frame embeddings (the
    modality-frontend stub per the assignment)."""
    from repro.serve.engine import Request, ServeEngine
    cfg = reduced_config(get_config("whisper-medium"))
    run = RunConfig()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg, run)
    engine = ServeEngine(cfg, run, params, batch_size=2, max_len=32, seed=0)
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((cfg.n_frames, cfg.d_model)).astype(np.float32)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=3, frontend={"frames": frames})
            for i in range(3)]
    outs = engine.serve(reqs)
    assert len(outs) == 3
    for c in outs:
        assert len(c.tokens) == 3


# ---------------------------------------------------------------------------
# data pipeline contract
# ---------------------------------------------------------------------------

def test_token_pipeline_deterministic_and_resumable():
    from repro.data.tokens import SyntheticCorpus, TokenPipelineConfig
    cfg = TokenPipelineConfig(vocab=1000, seq_len=16, global_batch=4, seed=3)
    c1, c2 = SyntheticCorpus(cfg), SyntheticCorpus(cfg)
    for step in (0, 5, 1000):
        a, b = c1.batch_at(step), c2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shards partition the global batch deterministically
    sh0 = SyntheticCorpus(TokenPipelineConfig(1000, 16, 4, 0, 2, 3)).batch_at(7)
    sh1 = SyntheticCorpus(TokenPipelineConfig(1000, 16, 4, 1, 2, 3)).batch_at(7)
    assert sh0["tokens"].shape == (2, 16)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])


def test_prefetch_iterator_matches_batch_at():
    from repro.data.tokens import (PrefetchIterator, SyntheticCorpus,
                                   TokenPipelineConfig)
    corpus = SyntheticCorpus(TokenPipelineConfig(100, 8, 2, seed=1))
    it = PrefetchIterator(corpus, start_step=4)
    try:
        for s in (4, 5, 6):
            np.testing.assert_array_equal(next(it)["tokens"],
                                          corpus.batch_at(s)["tokens"])
    finally:
        it.close()


# ---------------------------------------------------------------------------
# pipeline parallelism (separate process: needs >1 host device)
# ---------------------------------------------------------------------------

PP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pipe",))
P, M, mb, d = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (P, d, d)) / jnp.sqrt(d)

def stage(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))
out = pipeline_apply(stage, ws, x, mesh=mesh)

ref = x
for i in range(P):
    ref = jnp.tanh(ref @ ws[i])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("PP-OK")
"""


def test_gpipe_pipeline_matches_sequential():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", PP_SCRIPT],
                          capture_output=True, text=True, timeout=600,
                          env=env, cwd="/root/repo")
    assert "PP-OK" in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# dry-run machinery at host scale (1 device): the same builders lower+compile
# ---------------------------------------------------------------------------

def test_dryrun_cell_builders_compile_at_host_scale():
    """The exact dry-run code path (specs -> shardings -> lower -> compile ->
    cost/memory analyses) on a 1x1 mesh with a reduced arch."""
    import os
    script = r"""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import registry
from repro.models.registry import reduced_config
from repro.parallel import sharding as shd
from repro.train import optim
from repro.train.loop import TrainState, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = reduced_config(get_config("qwen2-1.5b"))
run = RunConfig(tp=1)
mesh = jax.make_mesh((1, 1), ("data", "model"))
shape = ShapeConfig("t", 32, 2, "train")
opt = optim.adam(1e-3)
params_shape = registry.params_specs(cfg, run)
opt_shape = jax.eval_shape(opt.init, params_shape)
state_sds = TrainState(params=params_shape, opt=opt_shape, gc=None,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
pspecs = shd.param_partition_specs(params_shape, tp_size=1)
state_specs = TrainState(params=pspecs,
                         opt=type(opt_shape)(step=P(), mu=pspecs, nu=pspecs),
                         gc=None, step=P())
batch = registry.train_batch_specs(cfg, run, shape)
bspecs = {k: P(("data",), *([None] * (len(v.shape) - 1)))
          for k, v in batch.items()}
to = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda s: isinstance(s, P))
with jax.set_mesh(mesh):
    step = make_train_step(cfg, run, opt)
    c = jax.jit(step, in_shardings=(to(state_specs), to(bspecs)),
                out_shardings=(to(state_specs), None)).lower(
        state_sds, batch).compile()
assert c.cost_analysis().get("flops", 0) > 0
assert c.memory_analysis().temp_size_in_bytes >= 0
print("DRYRUN-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=env, cwd="/root/repo")
    assert "DRYRUN-OK" in proc.stdout, proc.stderr[-2000:]


# ---------------------------------------------------------------------------
# collective-bytes HLO parser
# ---------------------------------------------------------------------------

def test_collective_bytes_parser_on_synthetic_hlo():
    from repro.parallel.collectives import collective_bytes
    hlo = """
  %ar = f32[256,1024]{1,0} all-reduce(f32[256,1024]{1,0} %x), replica_groups={}
  %ag.1 = bf16[8,128] all-gather(bf16[4,128] %y), dimensions={0}
  %st = (f32[16], f32[16]) all-reduce-start(f32[16] %z)
  %dn = f32[16] all-reduce-done((f32[16], f32[16]) %st)
  %cp = u8[64]{0} collective-permute(u8[64]{0} %w), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 256 * 1024 * 4 + 16 * 4 * 2
    assert out["bytes"]["all-gather"] == 8 * 128 * 2
    assert out["bytes"]["collective-permute"] == 64
    assert out["counts"]["all-reduce"] == 2  # start counted, done skipped


# ---------------------------------------------------------------------------
# KV cache paging + PCA-GAE page archive
# ---------------------------------------------------------------------------

def test_kv_page_compression_guarantee():
    from repro.runtime.kvcache import (PAGE_TOKENS, compress_pages,
                                       decompress_pages, paginate, unpaginate)
    rng = np.random.default_rng(0)
    kv = rng.standard_normal((2, 64, 2, 16)).astype(np.float32)
    pages = paginate(kv)
    assert pages.shape == (2, 4, PAGE_TOKENS * 2 * 16)
    np.testing.assert_array_equal(unpaginate(pages, 2, 16), kv)
    flat = pages.reshape(-1, pages.shape[-1])
    tau = 0.25
    recon, store = compress_pages(flat, tau=tau, page_shape=(PAGE_TOKENS, 2, 16))
    errs = np.linalg.norm(flat - recon, axis=1)
    assert errs.max() <= tau * (1 + 1e-5)
    # decode path reproduces the encoder's reconstruction
    np.testing.assert_allclose(decompress_pages(store), recon, atol=1e-5)
    assert 0 < store.nbytes() < store.raw_nbytes()
